//! NSGA-II core machinery (Deb et al. 2000): Pareto domination, fast
//! non-dominated sorting, crowding distance, the crowded-comparison
//! tournament, simulated binary crossover (SBX) and polynomial mutation —
//! the genetic operators the paper uses (§4.2: crossover rate 1.0,
//! η_b = 15, mutation rate 0.01, η_p = 20).
//!
//! All objectives are *minimized*.

use crate::util::rng::Pcg64;
use std::cmp::Ordering;

/// Total order over objective values with **NaN ranked strictly worst**
/// (minimization, so NaN compares greater than everything, including
/// +∞). A failed simulator reporting NaN must lose every comparison —
/// never panic one — so a single bad evaluation cannot crash or pollute
/// the MOEA. Built on `f64::total_cmp`, with the NaN cases made
/// sign-independent (`total_cmp` alone would rank a negative NaN *best*).
fn nan_worst(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

/// One evaluated solution: decision vector + objective vector.
#[derive(Clone, Debug, PartialEq)]
pub struct Individual {
    pub point: Vec<f64>,
    pub objectives: Vec<f64>,
}

/// True iff `a` Pareto-dominates `b` (no worse in all objectives, strictly
/// better in at least one; minimization).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort. Returns fronts as index lists; front 0 is the
/// Pareto front. O(M·N²) like the original.
///
/// Individuals with any NaN objective are ranked **strictly worst**: they
/// are excluded from domination comparisons (NaN is incomparable, so they
/// would otherwise masquerade as non-dominated and land in front 0) and
/// appended as one final front after every finite-objective front.
pub fn fast_non_dominated_sort(objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let (clean, bad): (Vec<usize>, Vec<usize>) =
        (0..n).partition(|&i| !objs[i].iter().any(|x| x.is_nan()));
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut count = vec![0usize; n]; // how many dominate i
    for (ci, &i) in clean.iter().enumerate() {
        for &j in &clean[ci + 1..] {
            if dominates(&objs[i], &objs[j]) {
                dominated_by[i].push(j);
                count[j] += 1;
            } else if dominates(&objs[j], &objs[i]) {
                dominated_by[j].push(i);
                count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = clean.iter().copied().filter(|&i| count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                count[j] -= 1;
                if count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    if !bad.is_empty() {
        fronts.push(bad);
    }
    fronts
}

/// Crowding distance of each member of a front (`objs[front[k]]`).
/// Boundary solutions get `f64::INFINITY`.
pub fn crowding_distance(objs: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let m = if front.is_empty() { 0 } else { objs[front[0]].len() };
    let n = front.len();
    let mut dist = vec![0.0f64; n];
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    for obj in 0..m {
        let mut order: Vec<usize> = (0..n).collect();
        // NaN objectives sort strictly worst instead of panicking the
        // comparator — one bad simulator result must not kill the MOEA.
        order.sort_by(|&a, &b| nan_worst(objs[front[a]][obj], objs[front[b]][obj]));
        let lo = objs[front[order[0]]][obj];
        let hi = objs[front[order[n - 1]]][obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[n - 1]] = f64::INFINITY;
        let span = hi - lo;
        // A NaN span (a NaN objective at the worst end) skips the
        // objective exactly like a degenerate zero-width one.
        if span.is_nan() || span <= 0.0 {
            continue;
        }
        for k in 1..n - 1 {
            let prev = objs[front[order[k - 1]]][obj];
            let next = objs[front[order[k + 1]]][obj];
            dist[order[k]] += (next - prev) / span;
        }
    }
    dist
}

/// NSGA-II environmental selection: keep the best `n` of `pop` by
/// (front rank, crowding distance). This is the archive-truncation step of
/// the paper's asynchronous update.
pub fn environmental_selection(pop: Vec<Individual>, n: usize) -> Vec<Individual> {
    if pop.len() <= n {
        return pop;
    }
    let objs: Vec<Vec<f64>> = pop.iter().map(|i| i.objectives.clone()).collect();
    let fronts = fast_non_dominated_sort(&objs);
    let mut keep: Vec<usize> = Vec::with_capacity(n);
    for front in fronts {
        if keep.len() + front.len() <= n {
            keep.extend(front);
        } else {
            // Partial front: take the most crowded-distant members,
            // descending with NaN distances last — a NaN crowding value
            // must be truncated first, never panic the comparator.
            let dist = crowding_distance(&objs, &front);
            let mut idx: Vec<usize> = (0..front.len()).collect();
            idx.sort_by(|&a, &b| match (dist[a].is_nan(), dist[b].is_nan()) {
                (true, true) => Ordering::Equal,
                (true, false) => Ordering::Greater,
                (false, true) => Ordering::Less,
                (false, false) => dist[b].total_cmp(&dist[a]),
            });
            for &k in idx.iter().take(n - keep.len()) {
                keep.push(front[k]);
            }
            break;
        }
    }
    let mut taken: Vec<Option<Individual>> = pop.into_iter().map(Some).collect();
    keep.iter().map(|&i| taken[i].take().unwrap()).collect()
}

/// Rank + crowding for a whole population (used by the tournament).
fn rank_and_crowding(objs: &[Vec<f64>]) -> (Vec<usize>, Vec<f64>) {
    let fronts = fast_non_dominated_sort(objs);
    let mut rank = vec![0usize; objs.len()];
    let mut crowd = vec![0.0f64; objs.len()];
    for (r, front) in fronts.iter().enumerate() {
        let dist = crowding_distance(objs, front);
        for (k, &i) in front.iter().enumerate() {
            rank[i] = r;
            crowd[i] = dist[k];
        }
    }
    (rank, crowd)
}

/// Binary tournament with the crowded-comparison operator: lower rank wins;
/// ties broken by larger crowding distance.
pub struct CrowdedTournament {
    rank: Vec<usize>,
    crowd: Vec<f64>,
    n: usize,
}

impl CrowdedTournament {
    pub fn new(pop: &[Individual]) -> Self {
        let objs: Vec<Vec<f64>> = pop.iter().map(|i| i.objectives.clone()).collect();
        let (rank, crowd) = rank_and_crowding(&objs);
        Self { rank, crowd, n: pop.len() }
    }

    pub fn select(&self, rng: &mut Pcg64) -> usize {
        let a = rng.below(self.n as u64) as usize;
        let b = rng.below(self.n as u64) as usize;
        if self.rank[a] < self.rank[b] {
            a
        } else if self.rank[b] < self.rank[a] {
            b
        } else if self.crowd[a] >= self.crowd[b] {
            a
        } else {
            b
        }
    }
}

/// Simulated binary crossover (Deb & Agrawal 1995). Returns two children.
/// Applied per-variable with probability 0.5, as in the reference
/// implementation; bounds are enforced by clipping.
pub fn sbx_crossover(
    p1: &[f64],
    p2: &[f64],
    bounds: &[(f64, f64)],
    eta_c: f64,
    rng: &mut Pcg64,
) -> (Vec<f64>, Vec<f64>) {
    let d = p1.len();
    let mut c1 = p1.to_vec();
    let mut c2 = p2.to_vec();
    for i in 0..d {
        if rng.uniform() > 0.5 || (p1[i] - p2[i]).abs() < 1e-14 {
            continue;
        }
        let u: f64 = rng.uniform();
        let beta = if u <= 0.5 {
            (2.0 * u).powf(1.0 / (eta_c + 1.0))
        } else {
            (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta_c + 1.0))
        };
        let (lo, hi) = bounds[i];
        let x1 = 0.5 * ((1.0 + beta) * p1[i] + (1.0 - beta) * p2[i]);
        let x2 = 0.5 * ((1.0 - beta) * p1[i] + (1.0 + beta) * p2[i]);
        c1[i] = x1.clamp(lo, hi);
        c2[i] = x2.clamp(lo, hi);
    }
    (c1, c2)
}

/// Polynomial mutation (Deb 2001): each variable mutates with probability
/// `rate`; perturbation magnitude is governed by η_m.
pub fn polynomial_mutation(
    x: &mut [f64],
    bounds: &[(f64, f64)],
    rate: f64,
    eta_m: f64,
    rng: &mut Pcg64,
) {
    for i in 0..x.len() {
        if rng.uniform() >= rate {
            continue;
        }
        let (lo, hi) = bounds[i];
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        let u: f64 = rng.uniform();
        let delta = if u < 0.5 {
            (2.0 * u).powf(1.0 / (eta_m + 1.0)) - 1.0
        } else {
            1.0 - (2.0 * (1.0 - u)).powf(1.0 / (eta_m + 1.0))
        };
        x[i] = (x[i] + delta * span).clamp(lo, hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(objs: &[f64]) -> Individual {
        Individual { point: vec![], objectives: objs.to_vec() }
    }

    #[test]
    fn domination_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 2.0], &[2.0, 1.0])); // incomparable
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal
    }

    #[test]
    fn sorting_splits_fronts_correctly() {
        // f0: (1,1); f1: (2,2) and (1,3)? — (1,3): (1,1) dominates it.
        let objs = vec![
            vec![1.0, 1.0], // 0 — front 0
            vec![2.0, 2.0], // 1 — dominated by 0
            vec![0.5, 3.0], // 2 — front 0 (incomparable with 0)
            vec![3.0, 3.0], // 3 — dominated by all above
        ];
        let fronts = fast_non_dominated_sort(&objs);
        assert_eq!(fronts[0], vec![0, 2]);
        assert_eq!(fronts[1], vec![1]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn crowding_boundary_infinite_middle_finite() {
        let objs = vec![
            vec![0.0, 4.0],
            vec![1.0, 2.0],
            vec![2.0, 1.0],
            vec![4.0, 0.0],
        ];
        let front: Vec<usize> = vec![0, 1, 2, 3];
        let d = crowding_distance(&objs, &front);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
        assert!(d[2].is_finite() && d[2] > 0.0);
    }

    #[test]
    fn environmental_selection_keeps_first_front() {
        let pop = vec![
            ind(&[1.0, 1.0]),
            ind(&[5.0, 5.0]),
            ind(&[0.5, 2.0]),
            ind(&[4.0, 6.0]),
        ];
        let kept = environmental_selection(pop, 2);
        let objs: Vec<Vec<f64>> = kept.iter().map(|i| i.objectives.clone()).collect();
        assert!(objs.contains(&vec![1.0, 1.0]));
        assert!(objs.contains(&vec![0.5, 2.0]));
    }

    #[test]
    fn environmental_selection_uses_crowding_within_front() {
        // Five mutually non-dominated points on a line; keeping 3 must
        // retain both extremes (infinite crowding).
        let pop = vec![
            ind(&[0.0, 4.0]),
            ind(&[1.0, 3.0]),
            ind(&[1.1, 2.9]), // crowded next to previous
            ind(&[2.0, 2.0]),
            ind(&[4.0, 0.0]),
        ];
        let kept = environmental_selection(pop, 3);
        let objs: Vec<Vec<f64>> = kept.iter().map(|i| i.objectives.clone()).collect();
        assert!(objs.contains(&vec![0.0, 4.0]));
        assert!(objs.contains(&vec![4.0, 0.0]));
    }

    #[test]
    fn sbx_children_within_bounds_and_mean_preserving() {
        let mut rng = Pcg64::new(5);
        let bounds = vec![(0.0, 1.0); 8];
        let p1 = vec![0.2; 8];
        let p2 = vec![0.8; 8];
        for _ in 0..200 {
            let (c1, c2) = sbx_crossover(&p1, &p2, &bounds, 15.0, &mut rng);
            for i in 0..8 {
                assert!((0.0..=1.0).contains(&c1[i]));
                assert!((0.0..=1.0).contains(&c2[i]));
                // SBX is mean-preserving before clipping; with these
                // parents clipping is rare, so allow small tolerance.
                let mid = 0.5 * (c1[i] + c2[i]);
                assert!((mid - 0.5).abs() < 0.25, "mid {mid}");
            }
        }
    }

    #[test]
    fn mutation_respects_bounds_and_rate() {
        let mut rng = Pcg64::new(6);
        let bounds = vec![(0.0, 1.0); 1000];
        let mut x = vec![0.5; 1000];
        polynomial_mutation(&mut x, &bounds, 0.01, 20.0, &mut rng);
        let changed = x.iter().filter(|&&v| v != 0.5).count();
        // Expect ≈ 10 mutations of 1000 (allow wide slack).
        assert!(changed < 40, "changed {changed}");
        assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn tournament_prefers_lower_rank() {
        let pop = vec![ind(&[0.0, 0.0]), ind(&[1.0, 1.0]), ind(&[2.0, 2.0])];
        let t = CrowdedTournament::new(&pop);
        let mut rng = Pcg64::new(9);
        let mut wins = [0usize; 3];
        for _ in 0..3000 {
            wins[t.select(&mut rng)] += 1;
        }
        assert!(wins[0] > wins[1] && wins[1] > wins[2], "{wins:?}");
    }

    #[test]
    fn front0_is_exactly_the_pareto_optimal_set_property() {
        use crate::testutil::{check, pair, u64_in, usize_in};
        check(
            "front 0 == brute-force non-dominated set",
            pair(usize_in(1..40), u64_in(0..1000)),
            |&(n, seed)| {
                let mut rng = Pcg64::new(seed.wrapping_mul(0x9E37_79B9).wrapping_add(n as u64));
                // Quantized objectives force ties and exact duplicates —
                // the cases where a sloppy sort misclassifies.
                let m = 2 + (seed % 2) as usize;
                let objs: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..m).map(|_| (rng.uniform() * 4.0).floor()).collect())
                    .collect();
                let fronts = fast_non_dominated_sort(&objs);
                let brute: Vec<usize> = (0..n)
                    .filter(|&i| !(0..n).any(|j| dominates(&objs[j], &objs[i])))
                    .collect();
                let mut f0 = fronts[0].clone();
                f0.sort();
                f0 == brute
            },
        );
    }

    #[test]
    fn crowding_boundary_points_get_infinity_property() {
        use crate::testutil::{check, pair, u64_in, usize_in};
        check(
            "per-objective extremes get infinite crowding distance",
            pair(usize_in(3..30), u64_in(0..500)),
            |&(n, seed)| {
                let mut rng = Pcg64::new(seed ^ 0xC0FF_EE00);
                let objs: Vec<Vec<f64>> =
                    (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
                let front: Vec<usize> = (0..n).collect();
                let d = crowding_distance(&objs, &front);
                // Continuous draws are distinct a.s., so each objective has
                // a unique min and max — both must be infinite.
                for obj in 0..2 {
                    let mn = (0..n)
                        .min_by(|&a, &b| objs[a][obj].total_cmp(&objs[b][obj]))
                        .unwrap();
                    let mx = (0..n)
                        .max_by(|&a, &b| objs[a][obj].total_cmp(&objs[b][obj]))
                        .unwrap();
                    if !d[mn].is_infinite() || !d[mx].is_infinite() {
                        return false;
                    }
                }
                // Distances are nonnegative, and any non-extreme point is
                // finite (it has neighbours on both sides in every objective).
                d.iter().all(|&x| x >= 0.0)
                    && (0..n).all(|k| {
                        let extreme = (0..2).any(|obj| {
                            objs.iter().all(|o| o[obj] >= objs[k][obj])
                                || objs.iter().all(|o| o[obj] <= objs[k][obj])
                        });
                        extreme || d[k].is_finite()
                    })
            },
        );
    }

    #[test]
    fn nan_objectives_rank_strictly_worst_and_never_panic() {
        // Regression: a single NaN objective from a failed simulator used
        // to panic `partial_cmp().unwrap()`. Now NaN individuals form the
        // last front and are truncated first.
        let objs = vec![
            vec![1.0, 1.0],
            vec![f64::NAN, 0.5],
            vec![0.5, 2.0],
            vec![0.2, f64::NAN],
        ];
        let fronts = fast_non_dominated_sort(&objs);
        let last = fronts.last().unwrap().clone();
        assert_eq!(last, vec![1, 3], "NaN individuals form the final front");
        assert!(fronts[0].iter().all(|&i| i == 0 || i == 2));
        // Crowding over the NaN front must not panic.
        let d = crowding_distance(&objs, &last);
        assert_eq!(d.len(), 2);
        // Environmental selection drops the NaN individuals first.
        let pop = vec![
            ind(&[1.0, 1.0]),
            ind(&[f64::NAN, 0.5]),
            ind(&[0.5, 2.0]),
            ind(&[0.2, f64::NAN]),
        ];
        let kept = environmental_selection(pop, 2);
        assert_eq!(kept.len(), 2);
        assert!(
            kept.iter().all(|i| i.objectives.iter().all(|x| x.is_finite())),
            "{kept:?}"
        );
    }

    #[test]
    fn generation_with_nan_objectives_completes() {
        // The full generation machinery — sort, crowding, environmental
        // selection, tournament, offspring — survives a population where
        // some members carry NaN objectives (and NaN-objective parents
        // lose tournaments to any finite-objective member).
        let mut pop: Vec<Individual> = (0..8)
            .map(|i| Individual {
                point: vec![i as f64 / 8.0, 0.5],
                objectives: vec![i as f64, 8.0 - i as f64],
            })
            .collect();
        pop.push(Individual { point: vec![0.1, 0.2], objectives: vec![f64::NAN, f64::NAN] });
        pop.push(Individual { point: vec![0.3, 0.4], objectives: vec![0.5, f64::NAN] });
        let archive = environmental_selection(pop, 8);
        assert_eq!(archive.len(), 8);
        let t = CrowdedTournament::new(&archive);
        let mut rng = Pcg64::new(11);
        let bounds = vec![(0.0, 1.0); 2];
        for _ in 0..50 {
            let (i, j) = (t.select(&mut rng), t.select(&mut rng));
            let (c1, mut c2) =
                sbx_crossover(&archive[i].point, &archive[j].point, &bounds, 15.0, &mut rng);
            polynomial_mutation(&mut c2, &bounds, 0.1, 20.0, &mut rng);
            assert!(c1.iter().chain(&c2).all(|x| x.is_finite()));
        }
    }

    #[test]
    fn nan_worst_total_order_is_sign_independent() {
        use std::cmp::Ordering;
        let neg_nan = f64::from_bits(f64::NAN.to_bits() | (1 << 63));
        assert!(neg_nan.is_nan());
        for bad in [f64::NAN, neg_nan] {
            assert_eq!(nan_worst(bad, f64::INFINITY), Ordering::Greater);
            assert_eq!(nan_worst(f64::NEG_INFINITY, bad), Ordering::Less);
            assert_eq!(nan_worst(bad, bad), Ordering::Equal);
        }
        assert_eq!(nan_worst(1.0, 2.0), Ordering::Less);
    }

    #[test]
    fn sort_properties_hold_on_random_populations() {
        use crate::testutil::{check, usize_in};
        check("fronts partition and respect domination", usize_in(1..40), |&n| {
            let mut rng = Pcg64::new(n as u64 + 1);
            let objs: Vec<Vec<f64>> =
                (0..n).map(|_| vec![rng.uniform(), rng.uniform(), rng.uniform()]).collect();
            let fronts = fast_non_dominated_sort(&objs);
            // Partition check.
            let mut all: Vec<usize> = fronts.iter().flatten().cloned().collect();
            all.sort();
            if all != (0..n).collect::<Vec<_>>() {
                return false;
            }
            // No member of front k may be dominated by a member of front ≥ k.
            for (k, front) in fronts.iter().enumerate() {
                for &i in front {
                    for later in &fronts[k..] {
                        for &j in later {
                            if i != j && dominates(&objs[j], &objs[i]) && k == 0 {
                                return false;
                            }
                        }
                    }
                }
            }
            // Front 0 is mutually non-dominated.
            for &i in &fronts[0] {
                for &j in &fronts[0] {
                    if i != j && dominates(&objs[i], &objs[j]) && dominates(&objs[j], &objs[i]) {
                        return false;
                    }
                }
            }
            true
        });
    }
}
