//! NSGA-II as a CARAVAN search engine — with the paper's **asynchronous
//! generation update** (§4.2) and the conventional synchronous baseline.
//!
//! Asynchronous mode: start `P_ini` individuals; whenever `P_n` newly
//! evaluated individuals are available, archive them, truncate the archive
//! to `P_archive` (environmental selection) and immediately launch `P_n`
//! offspring. Consumers therefore never wait for generation barriers.
//!
//! Synchronous mode (the ablation baseline): the classic NSGA-II loop —
//! wait for *every* in-flight evaluation of a generation before updating,
//! which wastes CPU when evaluation times vary (the paper's motivation for
//! the asynchronous variant).
//!
//! Each individual is evaluated as a [`ParameterSet`](crate::tasklib::ParameterSet)
//! of `n_runs` seeded simulator runs whose objective vectors are averaged,
//! exactly as the paper's application (5 runs per individual).
//!
//! A [`JobEngine`] on the Job API v2: each run's job context is its
//! `(parameter-set id, run index)`, so neither the engine nor
//! [`PsetStore`] keeps a `TaskId` map. Failed runs arrive with a non-zero
//! `rc` (after any transparent scheduler-side retries) and contribute an
//! empty result vector, which the run-averaging skips.

use std::sync::{Arc, Mutex};

use super::nsga2::{
    environmental_selection, polynomial_mutation, sbx_crossover, CrowdedTournament, Individual,
};
use crate::api::{JobAdapter, JobEngine, JobSpec, Jobs};
use crate::tasklib::{PsetStore, TaskResult};
use crate::util::rng::Pcg64;

/// Job context of one run: `(parameter-set id, run index)`.
type RunCtx = (u64, usize);

/// MOEA configuration. Defaults mirror §4.2: `P_ini`=1000, `P_n`=500,
/// `P_archive`=1000, crossover rate 1.0 with η_b=15, mutation rate 0.01
/// with η_p=20, five runs per individual.
#[derive(Clone, Debug)]
pub struct MoeaConfig {
    pub p_ini: usize,
    pub p_n: usize,
    pub p_archive: usize,
    pub generations: usize,
    pub n_runs: usize,
    /// Decision-variable bounds (also the sampling box for generation 0).
    pub bounds: Vec<(f64, f64)>,
    pub eta_c: f64,
    pub eta_m: f64,
    pub crossover_rate: f64,
    pub mutation_rate: f64,
    pub seed: u64,
    /// Scheduler-side retries per run (simulator hiccups are retried
    /// transparently before the run counts as failed).
    pub run_retries: u32,
    /// `false` = the paper's asynchronous update; `true` = barrier baseline.
    pub synchronous: bool,
}

impl MoeaConfig {
    pub fn paper_defaults(bounds: Vec<(f64, f64)>) -> Self {
        Self {
            p_ini: 1000,
            p_n: 500,
            p_archive: 1000,
            generations: 40,
            n_runs: 5,
            bounds,
            eta_c: 15.0,
            eta_m: 20.0,
            crossover_rate: 1.0,
            mutation_rate: 0.01,
            seed: 0,
            run_retries: 0,
            synchronous: false,
        }
    }

    /// Scaled-down variant for tests/benches.
    pub fn small(bounds: Vec<(f64, f64)>) -> Self {
        Self {
            p_ini: 24,
            p_n: 12,
            p_archive: 24,
            generations: 6,
            n_runs: 2,
            ..Self::paper_defaults(bounds)
        }
    }
}

/// Result of an optimization run, shared out of the engine.
#[derive(Debug, Default)]
pub struct MoeaOutcome {
    /// Final archive (paper Fig. 5 plots its objective vectors).
    pub archive: Vec<Individual>,
    pub generations_done: usize,
    pub individuals_evaluated: usize,
    pub tasks_completed: usize,
    /// Per-generation mean objective vector of the archive (convergence trace).
    pub history: Vec<Vec<f64>>,
}

pub type SharedOutcome = Arc<Mutex<MoeaOutcome>>;

/// The engine. Construct with [`Nsga2Engine::new`], run it through
/// `run_scheduler` or `run_des`, then read the outcome handle.
pub struct Nsga2Engine {
    cfg: MoeaConfig,
    rng: Pcg64,
    psets: PsetStore,
    archive: Vec<Individual>,
    /// Evaluated individuals awaiting the next generation update.
    ready: Vec<Individual>,
    generation: usize,
    launched: usize,
    /// In-flight individuals (parameter sets not yet complete).
    in_flight: usize,
    tasks_completed: usize,
    outcome: SharedOutcome,
    seed_counter: u64,
}

impl Nsga2Engine {
    pub fn new(cfg: MoeaConfig) -> (JobAdapter<Self>, SharedOutcome) {
        assert!(cfg.p_n <= cfg.p_ini, "P_n must not exceed P_ini or the first update never fires");
        assert!(!cfg.bounds.is_empty());
        let outcome: SharedOutcome = Arc::new(Mutex::new(MoeaOutcome::default()));
        let rng = Pcg64::new(cfg.seed);
        (
            JobAdapter::new(Self {
                rng,
                psets: PsetStore::new(),
                archive: Vec::new(),
                ready: Vec::new(),
                generation: 0,
                launched: 0,
                in_flight: 0,
                tasks_completed: 0,
                outcome: Arc::clone(&outcome),
                seed_counter: 10_000,
                cfg,
            }),
            outcome,
        )
    }

    fn random_point(&mut self) -> Vec<f64> {
        self.cfg
            .bounds
            .iter()
            .map(|&(lo, hi)| self.rng.range_f64(lo, hi))
            .collect()
    }

    fn launch(&mut self, point: Vec<f64>, jobs: &mut Jobs<'_, RunCtx>) {
        let seed0 = self.seed_counter;
        self.seed_counter += self.cfg.n_runs as u64;
        let pid = self.psets.create_set(point.clone(), self.cfg.n_runs, seed0);
        for k in 0..self.cfg.n_runs {
            jobs.submit(
                JobSpec::eval(point.clone())
                    .seed(seed0 + k as u64)
                    .retries(self.cfg.run_retries),
                (pid, k),
            );
        }
        self.launched += 1;
        self.in_flight += 1;
    }

    /// Generate one offspring from the archive via tournament + SBX + mutation.
    fn make_offspring(&mut self, tournament: &CrowdedTournament) -> Vec<f64> {
        let i = tournament.select(&mut self.rng);
        let j = tournament.select(&mut self.rng);
        let (p1, p2) = (self.archive[i].point.clone(), self.archive[j].point.clone());
        let mut child = if self.rng.uniform() < self.cfg.crossover_rate {
            let (c1, c2) = sbx_crossover(&p1, &p2, &self.cfg.bounds, self.cfg.eta_c, &mut self.rng);
            if self.rng.uniform() < 0.5 {
                c1
            } else {
                c2
            }
        } else {
            p1
        };
        polynomial_mutation(
            &mut child,
            &self.cfg.bounds,
            self.cfg.mutation_rate,
            self.cfg.eta_m,
            &mut self.rng,
        );
        child
    }

    /// Archive the ready set and, if the update condition holds, run a
    /// generation update and launch offspring.
    fn maybe_update(&mut self, jobs: &mut Jobs<'_, RunCtx>) {
        loop {
            let threshold = if self.cfg.synchronous {
                // Barrier: wait until nothing is in flight.
                if self.in_flight > 0 {
                    return;
                }
                self.ready.len().max(1)
            } else {
                self.cfg.p_n
            };
            if self.ready.len() < threshold || self.generation >= self.cfg.generations {
                return;
            }
            // Take up to p_n ready individuals into the archive (sync mode
            // archives the whole generation at once).
            let take = if self.cfg.synchronous { self.ready.len() } else { self.cfg.p_n };
            let newly: Vec<Individual> = self.ready.drain(..take).collect();
            self.archive.extend(newly);
            let archive = std::mem::take(&mut self.archive);
            self.archive = environmental_selection(archive, self.cfg.p_archive);
            self.generation += 1;
            // Convergence trace: mean objectives of the archive.
            if let Some(first) = self.archive.first() {
                let m = first.objectives.len();
                let mut mean = vec![0.0; m];
                for ind in &self.archive {
                    for (a, b) in mean.iter_mut().zip(&ind.objectives) {
                        *a += b;
                    }
                }
                for a in &mut mean {
                    *a /= self.archive.len() as f64;
                }
                self.outcome.lock().unwrap().history.push(mean);
            }
            if self.generation >= self.cfg.generations {
                return;
            }
            // Launch P_n offspring.
            let tournament = CrowdedTournament::new(&self.archive);
            for _ in 0..self.cfg.p_n {
                let child = self.make_offspring(&tournament);
                self.launch(child, jobs);
            }
        }
    }
}

impl JobEngine for Nsga2Engine {
    type Ctx = RunCtx;

    fn start(&mut self, jobs: &mut Jobs<'_, RunCtx>) {
        for _ in 0..self.cfg.p_ini {
            let p = self.random_point();
            self.launch(p, jobs);
        }
    }

    fn on_done(&mut self, result: &TaskResult, (pid, run): RunCtx, jobs: &mut Jobs<'_, RunCtx>) {
        self.tasks_completed += 1;
        // Failed runs (after any transparent retries) contribute an empty
        // vector; mean_results skips them.
        let values = if result.ok() { result.results.clone() } else { Vec::new() };
        if let Some(ps) = self.psets.record_run(pid, run, values) {
            self.in_flight -= 1;
            let objectives = ps.mean_results();
            if objectives.is_empty() {
                // Every run of this individual failed: resubmit a fresh
                // random point so the generation pipeline keeps its size.
                crate::warnln!("individual with all-failed runs; resubmitting");
                let p = self.random_point();
                self.launch(p, jobs);
                return;
            }
            self.ready.push(Individual { point: ps.point, objectives });
            self.maybe_update(jobs);
        }
    }

    fn finish(&mut self) {
        // Stragglers beyond the final generation still carry information:
        // archive anything completed but never selected.
        let mut out = self.outcome.lock().unwrap();
        let mut archive = std::mem::take(&mut self.archive);
        archive.extend(self.ready.drain(..));
        out.archive = environmental_selection(archive, self.cfg.p_archive);
        out.generations_done = self.generation;
        out.individuals_evaluated = self.launched;
        out.tasks_completed = self.tasks_completed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerConfig;
    use crate::des::{run_des, DesConfig, DurationModel};
    use crate::tasklib::{Payload, TaskSpec};

    /// Synthetic bi-objective problem (convex front): f1 = mean(x),
    /// f2 = mean((1-x)²), plus seed jitter to exercise run-averaging.
    struct Toy2D;
    impl DurationModel for Toy2D {
        fn duration(&mut self, _t: &TaskSpec) -> f64 {
            1.0
        }
        fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
            match &t.payload {
                Payload::Eval { input, seed } => {
                    let n = input.len() as f64;
                    let f1 = input.iter().sum::<f64>() / n;
                    let f2 = input.iter().map(|x| (1.0 - x) * (1.0 - x)).sum::<f64>() / n;
                    let jitter = (*seed % 7) as f64 * 1e-6;
                    vec![f1 + jitter, f2 + jitter]
                }
                _ => vec![],
            }
        }
    }

    fn run_toy(synchronous: bool) -> (MoeaOutcome, usize) {
        let bounds = vec![(0.0, 1.0); 4];
        let mut cfg = MoeaConfig::small(bounds);
        cfg.synchronous = synchronous;
        cfg.seed = 3;
        let gens = cfg.generations;
        let (engine, outcome) = Nsga2Engine::new(cfg);
        let des_cfg = DesConfig::new(8);
        let r = run_des(&des_cfg, Box::new(engine), Box::new(Toy2D));
        assert!(!r.results.is_empty());
        let out = Arc::try_unwrap(outcome).unwrap().into_inner().unwrap();
        (out, gens)
    }

    #[test]
    fn async_moea_completes_generations_and_improves() {
        let (out, gens) = run_toy(false);
        assert_eq!(out.generations_done, gens);
        assert!(!out.archive.is_empty());
        assert!(out.individuals_evaluated >= 24 + 12 * (gens - 1));
        // Convergence: archive-mean f1+f2 should not get worse from first
        // to last generation (tolerant: toy problem, tiny population).
        let first: f64 = out.history.first().unwrap().iter().sum();
        let last: f64 = out.history.last().unwrap().iter().sum();
        assert!(last <= first + 0.05, "first {first} last {last}");
        // Final front near the true Pareto set: f1+f2 ≤ 1 + slack for all
        // archived points (true front satisfies f2 = (1-f1)² ≤ 1-f1 for
        // f1∈[0,1] ⇒ f1+f2 ≤ 1).
        for ind in &out.archive {
            let s = ind.objectives[0] + ind.objectives[1];
            assert!(s < 1.3, "objectives {:?}", ind.objectives);
        }
    }

    #[test]
    fn sync_moea_also_converges_but_is_barriered() {
        let (out, _) = run_toy(true);
        assert!(out.generations_done >= 1);
        assert!(!out.archive.is_empty());
    }

    #[test]
    fn failed_runs_are_skipped_by_run_averaging() {
        // Every third seed fails (rc 1 after retries = 0): the pset mean
        // must come from the surviving runs, and the optimizer must still
        // complete all generations.
        struct Flaky;
        impl DurationModel for Flaky {
            fn duration(&mut self, _t: &TaskSpec) -> f64 {
                1.0
            }
            fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
                Toy2D.results(t)
            }
            fn rc(&mut self, t: &TaskSpec) -> i32 {
                match &t.payload {
                    Payload::Eval { seed, .. } if seed % 3 == 0 => 1,
                    _ => 0,
                }
            }
        }
        let mut cfg = MoeaConfig::small(vec![(0.0, 1.0); 3]);
        cfg.n_runs = 3;
        cfg.generations = 3;
        let (engine, outcome) = Nsga2Engine::new(cfg);
        let r = run_des(&DesConfig::new(8), Box::new(engine), Box::new(Flaky));
        assert!(!r.results.is_empty());
        let out = outcome.lock().unwrap();
        assert_eq!(out.generations_done, 3);
        assert!(out
            .archive
            .iter()
            .all(|i| i.objectives.len() == 2 && i.objectives.iter().all(|o| o.is_finite())));
    }

    #[test]
    fn async_beats_sync_filling_rate_on_heavy_tailed_durations() {
        // The §4.2 motivation: with variable evaluation times, the barrier
        // wastes CPU. Heavy-tailed durations, same budget.
        struct HeavyTail {
            rng: Pcg64,
        }
        impl DurationModel for HeavyTail {
            fn duration(&mut self, _t: &TaskSpec) -> f64 {
                self.rng.power_law(5.0, 100.0, -2.0)
            }
            fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
                Toy2D.results(t)
            }
        }
        let run = |synchronous: bool| {
            let mut cfg = MoeaConfig::small(vec![(0.0, 1.0); 4]);
            cfg.synchronous = synchronous;
            cfg.p_ini = 64;
            cfg.p_n = 32;
            cfg.p_archive = 64;
            cfg.generations = 8;
            let (engine, _outcome) = Nsga2Engine::new(cfg);
            let des_cfg = DesConfig::new(32);
            let r = run_des(&des_cfg, Box::new(engine), Box::new(HeavyTail { rng: Pcg64::new(5) }));
            r.rate(32)
        };
        let (r_async, r_sync) = (run(false), run(true));
        assert!(
            r_async > r_sync + 0.1,
            "async filling {r_async} should clearly beat sync {r_sync}"
        );
    }

    #[test]
    fn works_on_threaded_scheduler_too() {
        // End-to-end through the real threads: tiny population, instant evals.
        use crate::scheduler::{run_scheduler, Executor};
        use std::sync::Arc as StdArc;
        struct EvalExec;
        impl Executor for EvalExec {
            fn run(&self, task: &TaskSpec, _c: usize) -> (Vec<f64>, i32) {
                match &task.payload {
                    Payload::Eval { input, .. } => {
                        let f1 = input.iter().sum::<f64>() / input.len() as f64;
                        let f2 =
                            input.iter().map(|x| (1.0 - x) * (1.0 - x)).sum::<f64>()
                                / input.len() as f64;
                        (vec![f1, f2], 0)
                    }
                    _ => (vec![], 1),
                }
            }
        }
        let mut cfg = MoeaConfig::small(vec![(0.0, 1.0); 3]);
        cfg.generations = 3;
        let (engine, outcome) = Nsga2Engine::new(cfg);
        let sched = SchedulerConfig {
            np: 4,
            consumers_per_buffer: 4,
            flush_interval_ms: 2,
            ..Default::default()
        };
        let report = run_scheduler(&sched, Box::new(engine), StdArc::new(EvalExec));
        assert!(!report.results.is_empty());
        let out = outcome.lock().unwrap();
        assert_eq!(out.generations_done, 3);
        assert!(!out.archive.is_empty());
    }
}
