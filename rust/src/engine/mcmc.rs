//! Markov-chain Monte Carlo sampling of a parameter space — one of the
//! paper's motivating use cases (§1, §2.1): sampling points must be chosen
//! *dynamically* from previous results, which a Map-Reduce framework can't
//! express but CARAVAN's callback flow can.
//!
//! This engine runs `walkers` independent Metropolis chains. The target
//! density is `exp(-f/temperature)` where `f` is the first value the
//! simulator reports (e.g. evacuation time): chains concentrate where the
//! simulated objective is low. Every proposal is one simulator task, so a
//! chain of length L × W walkers = L·W tasks, scheduled concurrently across
//! walkers while each walker's own chain stays sequential — the same
//! concurrency pattern as §2.3's "three concurrent lines of sequential
//! tasks".
//!
//! A [`JobEngine`] on the Job API v2: the walker index is the job context,
//! so the engine holds no `TaskId -> walker` map.

use std::sync::{Arc, Mutex};

use crate::api::{JobAdapter, JobEngine, JobSpec, Jobs};
use crate::tasklib::TaskResult;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct McmcConfig {
    pub walkers: usize,
    /// Proposals per walker (chain length, excluding the initial point).
    pub steps: usize,
    /// Proposal standard deviation, as a fraction of each bound's span.
    pub step_frac: f64,
    pub temperature: f64,
    pub bounds: Vec<(f64, f64)>,
    pub seed: u64,
}

impl McmcConfig {
    pub fn new(bounds: Vec<(f64, f64)>) -> Self {
        Self { walkers: 8, steps: 50, step_frac: 0.05, temperature: 1.0, bounds, seed: 0 }
    }
}

/// Chain output: accepted samples per walker + acceptance statistics.
#[derive(Debug, Default)]
pub struct McmcOutcome {
    /// One chain (sequence of accepted points) per walker.
    pub chains: Vec<Vec<Vec<f64>>>,
    /// Objective value trace per walker (parallel to `chains`).
    pub values: Vec<Vec<f64>>,
    pub proposals: usize,
    pub accepted: usize,
}

impl McmcOutcome {
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposals == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposals as f64
        }
    }

    /// All samples pooled across walkers.
    pub fn pooled(&self) -> Vec<&Vec<f64>> {
        self.chains.iter().flatten().collect()
    }
}

pub type SharedMcmc = Arc<Mutex<McmcOutcome>>;

struct Walker {
    current: Vec<f64>,
    current_f: f64,
    proposal: Vec<f64>,
    steps_done: usize,
    initialized: bool,
}

/// Metropolis engine. Each completed task triggers the accept/reject step
/// and the submission of the walker's next proposal (a callback chain).
pub struct McmcEngine {
    cfg: McmcConfig,
    rng: Pcg64,
    walkers: Vec<Walker>,
    outcome: SharedMcmc,
    seeds: u64,
}

impl McmcEngine {
    pub fn new(cfg: McmcConfig) -> (JobAdapter<Self>, SharedMcmc) {
        assert!(cfg.walkers > 0 && cfg.temperature > 0.0);
        let outcome: SharedMcmc = Arc::new(Mutex::new(McmcOutcome::default()));
        outcome.lock().unwrap().chains = vec![Vec::new(); cfg.walkers];
        outcome.lock().unwrap().values = vec![Vec::new(); cfg.walkers];
        let rng = Pcg64::new(cfg.seed);
        (
            JobAdapter::new(Self {
                rng,
                walkers: Vec::new(),
                outcome: Arc::clone(&outcome),
                seeds: 1,
                cfg,
            }),
            outcome,
        )
    }

    fn propose_from(&mut self, x: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(x.len());
        for (i, &(lo, hi)) in self.cfg.bounds.iter().enumerate() {
            let sigma = (hi - lo) * self.cfg.step_frac;
            let v = (x[i] + sigma * self.rng.normal()).clamp(lo, hi);
            out.push(v);
        }
        out
    }

    fn submit_eval(&mut self, walker: usize, point: Vec<f64>, jobs: &mut Jobs<'_, usize>) {
        let seed = self.seeds;
        self.seeds += 1;
        jobs.submit(JobSpec::eval(point).seed(seed), walker);
    }
}

impl JobEngine for McmcEngine {
    type Ctx = usize;

    fn start(&mut self, jobs: &mut Jobs<'_, usize>) {
        for w in 0..self.cfg.walkers {
            let init: Vec<f64> =
                self.cfg.bounds.iter().map(|&(lo, hi)| self.rng.range_f64(lo, hi)).collect();
            self.walkers.push(Walker {
                current: init.clone(),
                current_f: f64::INFINITY,
                proposal: init.clone(),
                steps_done: 0,
                initialized: false,
            });
            self.submit_eval(w, init, jobs);
        }
    }

    fn on_done(&mut self, result: &TaskResult, w: usize, jobs: &mut Jobs<'_, usize>) {
        let f = result.results.first().copied().unwrap_or(f64::INFINITY);
        let (accept, first_eval) = {
            let walker = &self.walkers[w];
            if !walker.initialized {
                (true, true)
            } else {
                let delta = f - walker.current_f;
                let p = (-delta / self.cfg.temperature).exp();
                (delta <= 0.0 || self.rng.uniform() < p, false)
            }
        };
        {
            let mut out = self.outcome.lock().unwrap();
            if !first_eval {
                out.proposals += 1;
                if accept {
                    out.accepted += 1;
                }
            }
        }
        {
            let walker = &mut self.walkers[w];
            walker.initialized = true;
            if accept {
                walker.current = walker.proposal.clone();
                walker.current_f = f;
            }
            let (cur, cf) = (walker.current.clone(), walker.current_f);
            let mut out = self.outcome.lock().unwrap();
            out.chains[w].push(cur);
            out.values[w].push(cf);
        }
        if self.walkers[w].steps_done < self.cfg.steps {
            self.walkers[w].steps_done += 1;
            let cur = self.walkers[w].current.clone();
            let prop = self.propose_from(&cur);
            self.walkers[w].proposal = prop.clone();
            self.submit_eval(w, prop, jobs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{run_des, DesConfig, DurationModel};
    use crate::tasklib::{Payload, TaskSpec};
    use crate::util::stats::nan_worst;

    /// Quadratic bowl: f = Σ (x−0.7)² — chains should concentrate near 0.7.
    struct Bowl;
    impl DurationModel for Bowl {
        fn duration(&mut self, _t: &TaskSpec) -> f64 {
            1.0
        }
        fn results(&mut self, t: &TaskSpec) -> Vec<f64> {
            match &t.payload {
                Payload::Eval { input, .. } => {
                    vec![input.iter().map(|x| (x - 0.7) * (x - 0.7)).sum::<f64>()]
                }
                _ => vec![],
            }
        }
    }

    #[test]
    fn chains_run_full_length_and_concentrate() {
        let mut cfg = McmcConfig::new(vec![(0.0, 1.0); 2]);
        cfg.walkers = 4;
        cfg.steps = 120;
        cfg.temperature = 0.01;
        cfg.step_frac = 0.1;
        cfg.seed = 2;
        let (engine, outcome) = McmcEngine::new(cfg);
        let r = run_des(&DesConfig::new(4), Box::new(engine), Box::new(Bowl));
        // walkers × (1 init + steps) tasks
        assert_eq!(r.results.len(), 4 * 121);
        let out = outcome.lock().unwrap();
        assert_eq!(out.chains.len(), 4);
        assert!(out.chains.iter().all(|c| c.len() == 121));
        assert!(out.proposals == 4 * 120);
        let rate = out.acceptance_rate();
        assert!(rate > 0.05 && rate < 0.99, "acceptance {rate}");
        // Second half of each chain should be near the optimum.
        for chain in &out.chains {
            let tail = &chain[chain.len() / 2..];
            let mean0 = tail.iter().map(|p| p[0]).sum::<f64>() / tail.len() as f64;
            assert!((mean0 - 0.7).abs() < 0.15, "mean {mean0}");
        }
    }

    #[test]
    fn walkers_are_sequential_chains() {
        // Each walker has at most one task in flight: with W walkers, no
        // schedule point may have more than W concurrent MCMC tasks.
        let mut cfg = McmcConfig::new(vec![(0.0, 1.0)]);
        cfg.walkers = 3;
        cfg.steps = 20;
        let (engine, _outcome) = McmcEngine::new(cfg);
        let r = run_des(&DesConfig::new(16), Box::new(engine), Box::new(Bowl));
        // Count max concurrency from the schedule trace.
        let mut events: Vec<(f64, i32)> = Vec::new();
        for iv in r.filling.intervals() {
            events.push((iv.begin, 1));
            events.push((iv.finish, -1));
        }
        // nan_worst, not `partial_cmp().unwrap()`: a NaN timestamp must
        // sort deterministically instead of panicking (float-ord rule).
        events.sort_by(|a, b| nan_worst(a.0, b.0).then(a.1.cmp(&b.1)));
        let (mut cur, mut max) = (0, 0);
        for (_, d) in events {
            cur += d;
            max = max.max(cur);
        }
        assert!(max <= 3, "max concurrency {max}");
    }

    #[test]
    fn schedule_event_sort_survives_nan_timestamps() {
        // Regression (mirrors the PR 4/6 NaN sweeps): the schedule-trace
        // sort above used `partial_cmp().unwrap()`, so a single NaN
        // begin/finish stamp panicked the analysis. With nan_worst the
        // NaN event sorts last and the finite prefix keeps its order.
        let mut events: Vec<(f64, i32)> =
            vec![(2.0, -1), (f64::NAN, 1), (1.0, 1), (2.0, 1), (1.0, -1)];
        events.sort_by(|a, b| nan_worst(a.0, b.0).then(a.1.cmp(&b.1)));
        assert_eq!(events[0], (1.0, -1));
        assert_eq!(events[1], (1.0, 1));
        assert!(events[4].0.is_nan(), "NaN event sorts last, never panics");
    }
}
