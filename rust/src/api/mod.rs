//! Job API v2 — typed submissions with priority, retry and cancellation.
//!
//! CARAVAN's promise (§2.1) is that search engines only say *what* to run
//! while the framework owns distribution. The original `TaskSink::submit
//! (Payload) -> TaskId` surface undercut that: every engine kept its own
//! `TaskId -> context` map, failed runs had no recourse beyond "engines
//! decide whether to resubmit", and there was no priority or cancellation.
//! This module is the redesigned surface:
//!
//! * [`JobSpec`] — a typed job description with a builder
//!   (`JobSpec::eval(point).priority(2).retries(3)`): payload plus
//!   priority, retry budget, optional timeout and an optional tag.
//! * [`JobSink`] — the submission surface both runtimes implement.
//!   It extends the legacy [`TaskSink`] (which still works — a plain
//!   `submit(payload)` is `submit_job(JobSpec::new(payload))`), adding
//!   `submit_job` and `cancel`.
//! * [`JobEngine`] — the typed engine trait: `submit` takes an
//!   engine-owned context value that is handed back with the final
//!   [`TaskResult`] in `on_done`. The framework keeps the `TaskId ->
//!   context` map exactly once (in [`JobAdapter`]), killing the per-engine
//!   `by_task` HashMaps.
//! * [`JobAdapter`] — wraps a [`JobEngine`] into the object-safe
//!   [`SearchEngine`] the runtimes drive, so typed engines run unchanged
//!   on the threaded scheduler and the DES.
//! * [`JobStatus`] — coarse lifecycle state surfaced through
//!   [`Session`](crate::engine::Session).
//!
//! Semantics owned by the scheduler (identical in both runtimes, see
//! [`crate::scheduler::protocol`]):
//!
//! * **priority** — queues at every tree level are priority-ordered
//!   (higher `priority` first, FIFO within a level);
//! * **retry** — a task finishing with `rc != 0` and remaining retries is
//!   re-queued at its leaf transparently; the final [`TaskResult`] carries
//!   the attempt index;
//! * **cancel** — best-effort: a cancelled task still queued anywhere in
//!   the tree is dropped (counted in `NodeStats::cancelled_dropped`) and
//!   completes with `rc == RC_CANCELLED`; a task already *running* has
//!   its attempt killed by the executor (counted in
//!   `NodeStats::cancelled_killed`) and reports `RC_CANCELLED` without
//!   consuming a retry.

#![warn(missing_docs)]

use std::collections::HashMap;

use crate::tasklib::{Payload, SearchEngine, TaskId, TaskResult, TaskSink, TaskSpec};

/// A typed job submission: what to run plus how to schedule it.
///
/// Built fluently; unset knobs keep scheduler defaults:
///
/// ```
/// use caravan::api::JobSpec;
///
/// let spec = JobSpec::eval(vec![0.2, 0.8])
///     .seed(7)        // RNG stream for the evaluation
///     .priority(2)    // higher runs first
///     .retries(3)     // transparent re-runs on rc != 0
///     .timeout(30.0); // per-attempt budget in (virtual) seconds
/// assert_eq!(spec.priority, 2);
/// assert_eq!(spec.max_retries, 3);
/// assert_eq!(spec.timeout_s, Some(30.0));
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// What the consumer executes (evaluation point, sleep, command line).
    pub payload: Payload,
    /// Scheduling priority: higher runs first (default 0). Ties are FIFO.
    pub priority: u8,
    /// Transparent scheduler-side resubmissions after `rc != 0` (default 0).
    pub max_retries: u32,
    /// Per-attempt wall/virtual-time budget. Enforced by the executors:
    /// the DES truncates the attempt at the budget with `rc == RC_TIMEOUT`;
    /// the external-process executor kills the child. Timed-out attempts
    /// consume a retry like any other failure.
    pub timeout_s: Option<f64>,
    /// Free-form label carried on the task (for logs and debugging).
    pub tag: Option<String>,
    /// Tenant class ([`crate::tenancy::ClassId`]): selects the job's
    /// queue lane (per-class policy + fair-share weight, see
    /// [`crate::config::SchedulerConfig::classes`]) and its admission
    /// quota at the session boundary. Default 0 (the default class).
    pub class: crate::tenancy::ClassId,
}

impl JobSpec {
    /// A job with the given payload and default scheduling knobs
    /// (priority 0, no retries, no timeout, no tag).
    pub fn new(payload: Payload) -> Self {
        Self {
            payload,
            priority: 0,
            max_retries: 0,
            timeout_s: None,
            tag: None,
            class: crate::tenancy::DEFAULT_CLASS,
        }
    }

    /// In-process evaluation of a parameter point (seed 0; see [`Self::seed`]).
    pub fn eval(input: Vec<f64>) -> Self {
        Self::new(Payload::Eval { input, seed: 0 })
    }

    /// Dummy sleep task (tests, §3 workloads).
    pub fn sleep(seconds: f64) -> Self {
        Self::new(Payload::Sleep { seconds })
    }

    /// External simulator command line (§2.2 contract).
    pub fn command(cmdline: impl Into<String>) -> Self {
        Self::new(Payload::Command { cmdline: cmdline.into() })
    }

    /// RNG stream selector for [`Payload::Eval`] (no-op on other payloads).
    pub fn seed(mut self, seed: u64) -> Self {
        if let Payload::Eval { seed: s, .. } = &mut self.payload {
            *s = seed;
        }
        self
    }

    /// Scheduling priority: higher runs first; ties are FIFO.
    pub fn priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    /// Transparent scheduler-side re-runs after a non-zero exit.
    pub fn retries(mut self, max_retries: u32) -> Self {
        self.max_retries = max_retries;
        self
    }

    /// Per-attempt budget in (virtual) seconds; overrunning attempts are
    /// killed with [`crate::tasklib::RC_TIMEOUT`] and retried if budget
    /// remains.
    pub fn timeout(mut self, seconds: f64) -> Self {
        self.timeout_s = Some(seconds);
        self
    }

    /// Free-form label carried on the task (logs and debugging).
    pub fn tag(mut self, tag: impl Into<String>) -> Self {
        self.tag = Some(tag.into());
        self
    }

    /// Tenant class the job belongs to (see
    /// [`crate::config::SchedulerConfig::classes`]).
    pub fn class(mut self, class: crate::tenancy::ClassId) -> Self {
        self.class = class;
        self
    }

    /// Materialize as a scheduler task with the given id (attempt 0; the
    /// scheduler stamps `enqueued_t` when the task first enters a queue).
    pub fn into_task(self, id: TaskId) -> TaskSpec {
        TaskSpec {
            id,
            payload: self.payload,
            priority: self.priority,
            max_retries: self.max_retries,
            attempt: 0,
            timeout_s: self.timeout_s,
            tag: self.tag,
            class: self.class,
            enqueued_t: None,
        }
    }
}

/// Where engines hand jobs to the scheduler. Extends the legacy
/// [`TaskSink`]: `sink.submit(payload)` still works and is equivalent to
/// `sink.submit_job(JobSpec::new(payload))`.
pub trait JobSink: TaskSink {
    /// Submit a typed job; mints and returns the task id.
    fn submit_job(&mut self, spec: JobSpec) -> TaskId;
    /// Request best-effort cancellation of a previously submitted job.
    /// If the task is still queued anywhere it is dropped; if it is
    /// already *running*, the leaf asks its executor to kill the attempt
    /// (the external-process executor kills the child within its poll
    /// interval) and no retry is ever consumed — an attempt that fails
    /// naturally while the cancel is pending reports `RC_CANCELLED`
    /// instead of retrying. The one exception: an attempt that *succeeds*
    /// before the kill lands keeps its real result; a job that already
    /// finished is unaffected.
    fn cancel(&mut self, id: TaskId);
}

/// Coarse lifecycle state of a job, surfaced through
/// [`Session::status`](crate::engine::Session::status).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Submitted; no final result yet (queued, running, or retrying).
    Queued,
    /// Finished with `rc == 0`.
    Done,
    /// Finished with a non-zero `rc` (after exhausting any retries).
    Failed,
    /// Dropped by a cancellation before it ran.
    Cancelled,
}

impl JobStatus {
    /// Classify a final [`TaskResult`] (done / failed / cancelled).
    pub fn from_result(r: &TaskResult) -> Self {
        if r.cancelled() {
            JobStatus::Cancelled
        } else if r.ok() {
            JobStatus::Done
        } else {
            JobStatus::Failed
        }
    }
}

/// The engine-facing submission surface handed to [`JobEngine`] callbacks:
/// a [`JobSink`] plus the framework-owned `TaskId -> context` map.
pub struct Jobs<'a, C> {
    sink: &'a mut dyn JobSink,
    ctx: &'a mut HashMap<TaskId, C>,
}

impl<C> Jobs<'_, C> {
    /// Submit a job together with an engine-owned context value; the
    /// context is returned with the final result in
    /// [`JobEngine::on_done`].
    pub fn submit(&mut self, spec: JobSpec, ctx: C) -> TaskId {
        let id = self.sink.submit_job(spec);
        self.ctx.insert(id, ctx);
        id
    }

    /// Best-effort cancellation (see [`JobSink::cancel`]). The context is
    /// *not* dropped here: every submitted job yields exactly one final
    /// result (normal or cancelled), which consumes it.
    pub fn cancel(&mut self, id: TaskId) {
        self.sink.cancel(id);
    }

    /// Jobs submitted but not yet completed (or cancelled).
    pub fn in_flight(&self) -> usize {
        self.ctx.len()
    }
}

/// A search engine on the v2 API: typed submissions, no id bookkeeping.
///
/// `on_done` receives the context value stored at submission alongside the
/// final [`TaskResult`] — which may be a transparent-retry survivor
/// (`result.attempt > 0`) or a cancellation (`result.cancelled()`).
pub trait JobEngine: Send {
    /// Engine-owned per-job context (a parameter point, a walker index…).
    type Ctx: Send;

    /// Called once before scheduling begins: stage the initial jobs.
    fn start(&mut self, jobs: &mut Jobs<'_, Self::Ctx>);

    /// Called with every job's *final* result (retry survivor or
    /// cancellation) and the context stored at submission.
    fn on_done(&mut self, result: &TaskResult, ctx: Self::Ctx, jobs: &mut Jobs<'_, Self::Ctx>);

    /// Polled between events by the threaded runtime (see
    /// [`SearchEngine::poll`]). Return `false` while the engine may still
    /// produce tasks spontaneously.
    fn poll(&mut self, jobs: &mut Jobs<'_, Self::Ctx>) -> bool {
        let _ = jobs;
        true
    }

    /// Called once after the scheduler drained all tasks.
    fn finish(&mut self) {}
}

/// Adapter running a typed [`JobEngine`] on the object-safe
/// [`SearchEngine`] interface both runtimes drive. Owns the single
/// `TaskId -> context` map so engines do not have to.
///
/// Derefs to the inner engine so constructors can return the adapter
/// without hiding engine-specific accessors.
pub struct JobAdapter<E: JobEngine> {
    engine: E,
    ctx: HashMap<TaskId, E::Ctx>,
}

impl<E: JobEngine> JobAdapter<E> {
    /// Wrap `engine` with a fresh (empty) context map.
    pub fn new(engine: E) -> Self {
        Self { engine, ctx: HashMap::new() }
    }

    /// The wrapped engine (also reachable through `Deref`).
    pub fn inner(&self) -> &E {
        &self.engine
    }
}

impl<E: JobEngine> std::ops::Deref for JobAdapter<E> {
    type Target = E;
    fn deref(&self) -> &E {
        &self.engine
    }
}

impl<E: JobEngine> std::ops::DerefMut for JobAdapter<E> {
    fn deref_mut(&mut self) -> &mut E {
        &mut self.engine
    }
}

impl<E: JobEngine> SearchEngine for JobAdapter<E> {
    fn start(&mut self, sink: &mut dyn JobSink) {
        let Self { engine, ctx } = self;
        engine.start(&mut Jobs { sink, ctx });
    }

    fn on_done(&mut self, result: &TaskResult, sink: &mut dyn JobSink) {
        let Self { engine, ctx } = self;
        // Retried attempts never reach the producer, so exactly one final
        // result consumes each context. A missing context means the result
        // was not submitted through this adapter — ignore it.
        if let Some(c) = ctx.remove(&result.id) {
            engine.on_done(result, c, &mut Jobs { sink, ctx });
        }
    }

    fn poll(&mut self, sink: &mut dyn JobSink) -> bool {
        let Self { engine, ctx } = self;
        engine.poll(&mut Jobs { sink, ctx })
    }

    fn finish(&mut self) {
        self.engine.finish();
    }
}

/// Box a typed engine as a runtime-ready [`SearchEngine`].
pub fn job_engine<E: JobEngine + 'static>(engine: E) -> Box<dyn SearchEngine> {
    Box::new(JobAdapter::new(engine))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasklib::VecSink;

    #[test]
    fn builder_sets_all_fields() {
        let spec = JobSpec::eval(vec![0.5, 1.0])
            .seed(7)
            .priority(3)
            .retries(2)
            .timeout(4.5)
            .tag("gen0");
        assert_eq!(spec.priority, 3);
        assert_eq!(spec.max_retries, 2);
        assert_eq!(spec.timeout_s, Some(4.5));
        assert_eq!(spec.tag.as_deref(), Some("gen0"));
        match &spec.payload {
            Payload::Eval { input, seed } => {
                assert_eq!(input, &vec![0.5, 1.0]);
                assert_eq!(*seed, 7);
            }
            other => panic!("unexpected payload {other:?}"),
        }
        let task = spec.into_task(9);
        assert_eq!(task.id, 9);
        assert_eq!(task.attempt, 0);
        assert_eq!(task.priority, 3);
    }

    #[test]
    fn seed_is_noop_on_non_eval_payloads() {
        let spec = JobSpec::sleep(1.0).seed(42);
        assert_eq!(spec.payload, Payload::Sleep { seconds: 1.0 });
    }

    #[test]
    fn adapter_round_trips_context() {
        struct Echo {
            got: Vec<(u64, String)>,
        }
        impl JobEngine for Echo {
            type Ctx = String;
            fn start(&mut self, jobs: &mut Jobs<'_, String>) {
                jobs.submit(JobSpec::sleep(1.0), "a".into());
                jobs.submit(JobSpec::sleep(2.0).priority(5), "b".into());
                assert_eq!(jobs.in_flight(), 2);
            }
            fn on_done(&mut self, r: &TaskResult, ctx: String, _jobs: &mut Jobs<'_, String>) {
                self.got.push((r.id, ctx));
            }
        }
        let mut adapter = JobAdapter::new(Echo { got: Vec::new() });
        let mut sink = VecSink::new();
        SearchEngine::start(&mut adapter, &mut sink);
        assert_eq!(sink.submitted.len(), 2);
        assert_eq!(sink.submitted[1].priority, 5);
        let r = TaskResult {
            id: 1,
            consumer: 0,
            results: vec![],
            begin: 0.0,
            finish: 1.0,
            rc: 0,
            attempt: 0,
            timed_out: false,
        };
        SearchEngine::on_done(&mut adapter, &r, &mut sink);
        assert_eq!(adapter.inner().got, vec![(1, "b".to_string())]);
        // Unknown ids (no context) are ignored, not a panic.
        let unknown = TaskResult { id: 99, ..r };
        SearchEngine::on_done(&mut adapter, &unknown, &mut sink);
        assert_eq!(adapter.inner().got.len(), 1);
    }

    #[test]
    fn status_from_result() {
        let ok = TaskResult {
            id: 0,
            consumer: 0,
            results: vec![],
            begin: 0.0,
            finish: 0.0,
            rc: 0,
            attempt: 0,
            timed_out: false,
        };
        assert_eq!(JobStatus::from_result(&ok), JobStatus::Done);
        let failed = TaskResult { rc: 3, ..ok.clone() };
        assert_eq!(JobStatus::from_result(&failed), JobStatus::Failed);
        let cancelled = TaskResult { rc: crate::tasklib::RC_CANCELLED, ..ok };
        assert_eq!(JobStatus::from_result(&cancelled), JobStatus::Cancelled);
    }
}
