//! Mini property-based testing harness (offline stand-in for `proptest`).
//!
//! [`check`] runs a property over `CARAVAN_PROP_CASES` (default 128)
//! randomly generated cases and, on failure, greedily shrinks the failing
//! input via the strategy's `shrink` before panicking with the seed, so a
//! failure reproduces with `CARAVAN_PROP_SEED=<seed>`.
//!
//! ```
//! use caravan::testutil::{check, vec_of, f64_in};
//! check("sum is finite", vec_of(f64_in(0.0, 1.0), 0..100), |xs| {
//!     xs.iter().sum::<f64>().is_finite()
//! });
//! ```

use crate::util::rng::Pcg64;

/// A generation + shrinking strategy for values of type `T`.
pub trait Strat {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate smaller versions of `v` (tried in order during shrinking).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

fn cases() -> usize {
    std::env::var("CARAVAN_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(128)
}

fn base_seed() -> u64 {
    std::env::var("CARAVAN_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xCA7A)
}

/// Run `prop` over generated cases; panic (with reproduction seed) on the
/// first — shrunk — counterexample.
pub fn check<S: Strat>(name: &str, strat: S, prop: impl Fn(&S::Value) -> bool) {
    let seed = base_seed();
    let mut rng = Pcg64::new(seed);
    for case in 0..cases() {
        let v = strat.generate(&mut rng);
        if !prop(&v) {
            let shrunk = shrink_loop(&strat, v, &prop);
            panic!(
                "property {name:?} failed at case {case} (CARAVAN_PROP_SEED={seed}):\n  counterexample: {shrunk:?}"
            );
        }
    }
}

fn shrink_loop<S: Strat>(strat: &S, mut v: S::Value, prop: &impl Fn(&S::Value) -> bool) -> S::Value {
    // Greedy descent: keep taking the first shrink candidate that still fails.
    'outer: loop {
        for cand in strat.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        return v;
    }
}

// ---------------------------------------------------------------- strategies

pub struct U64In(pub std::ops::Range<u64>);
pub struct UsizeIn(pub std::ops::Range<usize>);
pub struct F64In(pub f64, pub f64);
pub struct VecOf<S>(pub S, pub std::ops::Range<usize>);
pub struct Tuple2<A, B>(pub A, pub B);

pub fn u64_in(r: std::ops::Range<u64>) -> U64In {
    U64In(r)
}
pub fn usize_in(r: std::ops::Range<usize>) -> UsizeIn {
    UsizeIn(r)
}
pub fn f64_in(lo: f64, hi: f64) -> F64In {
    F64In(lo, hi)
}
pub fn vec_of<S: Strat>(s: S, len: std::ops::Range<usize>) -> VecOf<S> {
    VecOf(s, len)
}
pub fn pair<A: Strat, B: Strat>(a: A, b: B) -> Tuple2<A, B> {
    Tuple2(a, b)
}

impl Strat for U64In {
    type Value = u64;
    fn generate(&self, rng: &mut Pcg64) -> u64 {
        rng.range_u64(self.0.start, self.0.end)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0.start {
            out.push(self.0.start);
            out.push(self.0.start + (*v - self.0.start) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

impl Strat for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg64) -> usize {
        rng.range_u64(self.0.start as u64, self.0.end as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        U64In(self.0.start as u64..self.0.end as u64)
            .shrink(&(*v as u64))
            .into_iter()
            .map(|x| x as usize)
            .collect()
    }
}

impl Strat for F64In {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        rng.range_f64(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if *v != self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2.0);
        }
        out
    }
}

impl<S: Strat> Strat for VecOf<S> {
    type Value = Vec<S::Value>;
    fn generate(&self, rng: &mut Pcg64) -> Vec<S::Value> {
        let n = rng.range_u64(self.1.start as u64, self.1.end as u64) as usize;
        (0..n).map(|_| self.0.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        // Structural shrinks: halve, drop one element.
        if v.len() > self.1.start {
            let half = (v.len() / 2).max(self.1.start);
            out.push(v[..half].to_vec());
            for i in 0..v.len().min(8) {
                let mut c = v.clone();
                c.remove(i);
                if c.len() >= self.1.start {
                    out.push(c);
                }
            }
        }
        // Element-wise shrinks on the first few elements.
        for i in 0..v.len().min(4) {
            for cand in self.0.shrink(&v[i]) {
                let mut c = v.clone();
                c[i] = cand;
                out.push(c);
            }
        }
        out
    }
}

impl<A: Strat, B: Strat> Strat for Tuple2<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check("u64 in range", u64_in(3..10), |v| (3..10).contains(v));
        check("vec lens", vec_of(f64_in(0.0, 1.0), 0..5), |v| v.len() < 5);
        check("pairs", pair(usize_in(0..4), f64_in(-1.0, 1.0)), |(a, b)| {
            *a < 4 && (-1.0..1.0).contains(b)
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let res = std::panic::catch_unwind(|| {
            check("always ge 5 (false)", u64_in(0..100), |v| *v < 5 || *v >= 100)
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // Minimal counterexample is 5.
        assert!(msg.contains("counterexample: 5"), "{msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let res = std::panic::catch_unwind(|| {
            check("short vecs only (false)", vec_of(u64_in(0..3), 0..50), |v| v.len() < 3)
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("counterexample: [0, 0, 0]"), "{msg}");
    }
}
