//! # CARAVAN — a framework for comprehensive simulations on massive parallel machines
//!
//! Reproduction of Murase, Matsushima, Noda & Kamada (2018),
//! DOI 10.1007/978-3-030-20937-7_9, as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * [`api`] — the Job API v2: typed [`api::JobSpec`] submissions with
//!   priority, retry, timeout and cancellation; the typed
//!   [`api::JobEngine`] trait whose per-job context values replace
//!   engine-side `TaskId` maps.
//! * [`tasklib`] — the task model (`Task`, `TaskResult`, `ParameterSet`, `Run`)
//!   mirroring CARAVAN's Python API.
//! * [`scheduler`] — the paper's system contribution: a hierarchical
//!   producer → buffer → consumer scheduler (threads + channels standing in
//!   for flat-MPI ranks), with the job-filling-rate metric of Eq. (1).
//! * [`des`] — a virtual-time discrete-event simulation of the same scheduler
//!   topology, used to reproduce the K-computer scaling results (Fig. 3) at
//!   up to 16 384 simulated processes on a single host.
//! * [`engine`] — search engines: grid / random sweeps, NSGA-II with the
//!   paper's asynchronous generation update (§4.2), and MCMC sampling.
//! * [`evac`] — the CrowdWalk-like evacuation substrate: road networks,
//!   Dijkstra routing, a 1-D pedestrian-flow simulator, plan encoding and
//!   the three objective functions f1/f2/f3 (§4.3).
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   evacuation model (`artifacts/*.hlo.txt`) and executes it on the hot path.
//! * [`extproc`] — external-process simulator support (§2.2): command-line
//!   arguments, per-task temporary directories, `_results.txt` parsing.
//! * [`transport`] — the link layer under the distributed scheduler: a
//!   length-prefixed binary codec for the protocol messages and a
//!   [`transport::Transport`] trait with in-process channel, TCP and
//!   Unix-domain-socket implementations (see `scheduler::net` for the
//!   `caravan worker` runtime built on top).
//! * [`tenancy`] — multi-tenant serving: the [`tenancy::JobClass`]
//!   registry (per-class policy, fair-share weight, in-flight quota),
//!   the `ClassId` carried on every job/task, and the typed
//!   [`tenancy::Admission`] backpressure signal at the session boundary.
//! * [`workload`] — the TC1/TC2/TC3 synthetic workloads of §3.
//! * [`lint`] — `caravan lint`: a dependency-free static-analysis pass
//!   over the crate's own sources enforcing the determinism and
//!   NaN-safety invariants (float ordering, virtual-time purity,
//!   iteration-order determinism, panic budgets, panic-free protocol
//!   paths, no unsafe).
//! * [`check`] — `caravan check`: a bounded model checker that drives
//!   the pure protocol state machines through every message
//!   interleaving at a small bound (DFS + partial-order reduction,
//!   seeded schedule fuzzing beyond it), with invariant oracles and
//!   delta-debugged, replayable counterexample traces.
//! * [`util`] — self-contained infrastructure (deterministic RNG, statistics,
//!   JSON, CLI, logging) so the crate builds offline.

// The whole crate is safe Rust; the `no-unsafe` lint rule checks this
// attribute is present so the guarantee cannot silently rot.
#![forbid(unsafe_code)]

pub mod util;
pub mod api;
pub mod tasklib;
pub mod scheduler;
pub mod tenancy;
pub mod des;
pub mod workload;
pub mod engine;
pub mod evac;
pub mod runtime;
pub mod extproc;
pub mod transport;
pub mod config;
pub mod lint;
pub mod check;
pub mod testutil;
