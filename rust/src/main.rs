//! `caravan` — the command-line launcher.
//!
//! Subcommands:
//!   run <cmdline>   run one external command N times through the scheduler
//!                   (§2.2 contract: argv in, per-task temp dir,
//!                   `_results.txt` out); with --listen the buffer tree
//!                   runs in remote `caravan worker` processes instead
//!   worker <addr>   connect to a root's --listen endpoint and serve a
//!                   remote subtree until the run shuts down
//!   des             DES filling-rate experiment (Fig. 3 point)
//!   evac            evaluate one random evacuation plan (tiny|mini)
//!   info            print artifact + scenario inventory
//!   lint            determinism & NaN-safety static analysis over the
//!                   crate's own sources (exit 1 on violations; CI gates)
//!   check           bounded model checking of the scheduler protocol:
//!                   exhaustive interleaving search + seeded schedule
//!                   fuzzing with invariant oracles; violations shrink
//!                   to minimal replayable traces (exit 1; CI gates)
//!
//! Examples:
//!   caravan run "sh -c 'echo 1 > _results.txt'" --n 32 --np 4 --retries 2
//!   caravan run "sh -c 'true'" --n 64 --np 8 --listen uds:/tmp/cv.sock --workers 2
//!   caravan run "sh -c 'true'" --n 64 --np 8 --class web=4:strict:64,batch=1:aging:30
//!   caravan worker uds:/tmp/cv.sock
//!   caravan des --np 1024 --tc 2 --tasks-per-proc 100
//!   caravan evac --variant tiny --backend pjrt --seed 3
//!   caravan info
//!   caravan lint --fix-hints rust/src
//!   caravan check --scenario deep4 --faults steal,cancel,recall,kill --max-tasks 2

use std::sync::Arc;

use caravan::api::{JobSink, JobSpec};
use caravan::config::{fanout_label, ReshapePolicy, SchedPolicy, SchedulerConfig, TreeShape};
use caravan::des::{run_des, DesConfig, SleepDurations};
use caravan::evac::{build_scenario, EvacEvaluator, RustSimBackend, ScenarioParams, SimBackend};
use caravan::extproc::CommandExecutor;
use caravan::runtime::{ArtifactMeta, PjrtServer};
use caravan::scheduler::{
    connect_worker, run_scheduler, serve_scheduler, CancelSet, ExecOutcome, Executor,
    ServeOptions, SleepExecutor,
};
use caravan::tasklib::{Payload, SearchEngine, TaskResult, TaskSpec};
use caravan::tenancy::{parse_policy_flag, JobClass};
use caravan::transport::{Endpoint, Listener};
use caravan::util::cli::Args;
use caravan::util::rng::Pcg64;
use caravan::workload::{TestCase, TestCaseEngine};

struct RepeatCmd {
    n: usize,
    /// Registered class count; tasks are dealt round-robin over the
    /// classes so a `--class a=...,b=...` run exercises every lane.
    n_classes: usize,
    spec: JobSpec,
}

impl SearchEngine for RepeatCmd {
    fn start(&mut self, sink: &mut dyn JobSink) {
        for i in 0..self.n {
            let mut spec = self.spec.clone();
            if self.n_classes > 0 {
                spec = spec.class((i % self.n_classes) as u8);
            }
            sink.submit_job(spec);
        }
    }
    fn on_done(&mut self, r: &TaskResult, _s: &mut dyn JobSink) {
        caravan::info!(
            "task {} rc={} attempt={} results={:?}",
            r.id,
            r.rc,
            r.attempt,
            r.results
        );
    }
}

/// Worker-side payload dispatcher: dummy sleeps run through
/// [`SleepExecutor`], external commands through [`CommandExecutor`].
/// `Eval` payloads need an in-process evaluator the bare worker does not
/// carry, so they fail cleanly with rc 1 instead of panicking the
/// consumer thread.
struct WorkerExecutor {
    sleep: SleepExecutor,
    command: CommandExecutor,
}

impl Executor for WorkerExecutor {
    fn run(&self, task: &TaskSpec, consumer: usize) -> (Vec<f64>, i32) {
        match &task.payload {
            Payload::Sleep { .. } => self.sleep.run(task, consumer),
            Payload::Command { .. } => self.command.run(task, consumer),
            Payload::Eval { .. } => (Vec::new(), 1),
        }
    }

    fn run_cancellable(&self, task: &TaskSpec, consumer: usize, cancel: &CancelSet) -> ExecOutcome {
        match &task.payload {
            Payload::Sleep { .. } => self.sleep.run_cancellable(task, consumer, cancel),
            Payload::Command { .. } => self.command.run_cancellable(task, consumer, cancel),
            Payload::Eval { .. } => ExecOutcome { results: Vec::new(), rc: 1, timed_out: false },
        }
    }
}

fn usage() {
    eprintln!(
        "usage: caravan <run|worker|des|evac|info|lint|check> [--options] (--help prints this)

  run '<cmdline>'   run an external command through the scheduler
      --n N           number of tasks (default 10)
      --np N          consumer processes (default 4)
      --retries N     transparent scheduler-side retries per task on
                      rc != 0 (default 0); the final result carries the
                      attempt count
      --priority P    scheduling priority 0-255, higher runs first
                      (default 0)
      --timeout S     per-attempt budget in seconds; overrunning attempts
                      are killed with rc 124 and retried if retries remain
      --policy P      queue ordering: strict (default), deadline (least
                      timeout slack within a priority band), aging or
                      aging:SECONDS (deadline order + priority aging, one
                      level per SECONDS waited; prevents starvation)
      --class SPECS   comma-separated tenant classes, each
                      NAME=WEIGHT:POLICY[:QUOTA] (e.g.
                      'web=4:strict:64,batch=1:aging:30'): tasks are
                      dealt round-robin over the classes, queue pops
                      interleave proportionally to WEIGHT (weighted
                      fair share), POLICY orders each class's lane,
                      and QUOTA bounds the class's in-flight tasks
                      (0 or omitted = unbounded)
      --depth D|auto  buffer-tree depth; 'auto' runs a short calibration
                      (producer round trip + mean task duration) and lets
                      the controller pick depth and fanout
      --fanout F[,F2,..]  per-level interior fanout, root level first,
                      last value repeating deeper (one value = uniform;
                      the maximum is the bound under --depth auto)
      --reshape       re-shape the tree *online* when the measured lag or
                      task duration drifts: queued work is recalled with
                      its scheduling stamps intact, the tree is rebuilt,
                      and the work re-granted (drain-and-graft)
      --reshape-window S    rolling measurement window, virtual seconds
                            (default 10)
      --reshape-drift X     relative drift that may trigger a transition
                            (default 0.25)
      --reshape-cooldown S  minimum seconds between transitions
                            (default 30)
      --dispatch-batch N  tasks handed to a consumer per dispatch (v10
                      batched hot path; default 1 = one task per message)
      --no-coalesce   one ascent send per event instead of merging credit
                      requests and result batches into `Flush` frames
      --listen ADDR   serve the buffer tree over the wire instead of
                      in-process: bind ADDR (tcp:HOST:PORT or
                      uds:/path.sock), wait for --workers `caravan
                      worker` connections, and split the np consumers
                      across them
      --workers N     worker links to accept before starting (default 1)

  worker <addr>     connect to a root's --listen endpoint and serve a
                    remote subtree (buffer tree + consumers) until the
                    root shuts the run down
      --np N          consumer share to offer (default: root decides)
      --time-scale S  real seconds per virtual second for dummy Sleep
                      payloads; must match the root (default 1.0)

  des               DES filling-rate experiment (Fig. 3 point)
      --np N --tc 1|2|3 --tasks-per-proc N --depth D|auto
      --fanout F[,F2,..] --steal --steal-round-robin --direct --seed S
      --dispatch-batch N --no-coalesce  (as for run; the batched hot
                      path is modelled event-for-event in the DES)
      --link-latency S[,S2,..]  per-edge one-way latency in seconds,
                      root-down (first = producer<->root edge, last
                      repeats deeper); models multi-host trees
      --policy strict|deadline|aging[:SECONDS]
      --reshape [--reshape-window S --reshape-drift X
                 --reshape-cooldown S]   (as for run; virtual time)

  evac              evaluate one random evacuation plan
      --variant tiny|mini --backend rust|pjrt --seed S
      --scenario-seed S   seed for the generated road network (default 1)

  info              print artifact + scenario inventory
      --artifacts DIR     artifact directory to inspect (default
                          'artifacts')

  lint [PATHS..]    static-analysis pass over the crate's own sources:
                    determinism & NaN-safety rules (float-ord,
                    wall-clock, hash-iter, unwrap-budget, no-unsafe).
                    With no PATHS, scans rust/src + rust/tests +
                    rust/benches (or src/tests/benches from inside
                    rust/). Exit 0 clean, 1 on violations, 2 on
                    usage/IO errors.
      --fix-hints     print a suggested fix under every violation

  check             bounded model checking of the scheduler protocol:
                    exhaustive DFS over message interleavings (with
                    partial-order reduction), then seeded schedule
                    fuzzing, with invariant oracles after every step.
                    Exit 0 when every oracle held, 1 on a violation
                    (with a minimized replayable trace), 2 on usage/IO
                    errors — CI gates on this.
      --scenario S    model topology: flat2 (default), batched2 (the
                      dispatch_batch=2 + coalesced-ascent hot path),
                      deep4, or 'all'
      --max-tasks N   tasks the model engine submits (1..=16, default 3)
      --max-depth D   DFS schedule-depth bound (default 400)
      --max-states N  unique-state budget for the DFS (default 200000)
      --faults LIST   comma-separated fault events to inject:
                      steal,cancel,recall,kill or 'none' (default
                      steal,cancel,recall; kill needs --scenario deep4)
      --seeds N       fuzz schedules after a clean DFS (default 64;
                      0 disables fuzzing)
      --fuzz-steps N  per-schedule event cap for the fuzzer (default 5000)
      --inject-bug B  arm a deliberately seeded protocol bug
                      (drop-returned[:N]) to prove the oracles catch it
      --replay FILE   replay a trace artifact instead of exploring
      --trace-out F   also write the minimized counterexample trace to F"
    );
}

/// Apply `--reshape` (and its `--reshape-*` tuning knobs) to a scheduler
/// config. Any tuning knob implies `--reshape` itself.
fn apply_reshape(args: &Args, cfg: &mut SchedulerConfig) {
    let tuned = args.get_opt("reshape-window").is_some()
        || args.get_opt("reshape-drift").is_some()
        || args.get_opt("reshape-cooldown").is_some();
    if !args.has_flag("reshape") && !tuned {
        return;
    }
    let d = ReshapePolicy::default();
    cfg.reshape = Some(ReshapePolicy {
        window: args.get_f64("reshape-window", d.window),
        drift_threshold: args.get_f64("reshape-drift", d.drift_threshold),
        cooldown: args.get_f64("reshape-cooldown", d.cooldown),
    });
}

/// Apply `--depth D|auto` and `--fanout F` to a scheduler config.
/// `--depth auto` turns on adaptive tree shaping: a short calibration
/// phase measures the producer round trip and mean task duration, and the
/// controller picks depth/fanout — the user never tunes the shape.
fn apply_shape(args: &Args, cfg: &mut SchedulerConfig) {
    cfg.fanout = args.get_list_usize("fanout", &cfg.fanout);
    if cfg.fanout.is_empty() || cfg.fanout.iter().any(|&f| f == 0) {
        eprintln!("--fanout: expected positive values, e.g. 8 or 4,8");
        std::process::exit(2);
    }
    match args.get_opt("depth") {
        None => {}
        Some("auto") => cfg.shape = TreeShape::Auto,
        Some(d) => {
            cfg.depth = d.parse().unwrap_or_else(|_| {
                eprintln!("--depth: expected an integer or 'auto', got {d:?}");
                std::process::exit(2);
            })
        }
    }
}

fn parse_policy(args: &Args) -> SchedPolicy {
    match args.get_opt("policy") {
        None => SchedPolicy::Strict,
        Some(s) => parse_policy_flag("--policy", s).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        }),
    }
}

/// Render a policy the way the CLI accepts it (`--policy` / `--class`).
fn policy_label(p: SchedPolicy) -> String {
    match p {
        SchedPolicy::Strict => "strict".to_string(),
        SchedPolicy::Deadline => "deadline".to_string(),
        SchedPolicy::Aging { step } => format!("aging:{step}"),
    }
}

/// Apply the hot-path batching knobs: `--dispatch-batch N` (tasks per
/// consumer dispatch; 1 restores the pre-v10 one-message-per-task path)
/// and `--no-coalesce` (one ascent send per event instead of merged
/// credit+result `Flush` frames).
fn apply_batching(args: &Args, cfg: &mut SchedulerConfig) {
    cfg.dispatch_batch = args.get_usize("dispatch-batch", cfg.dispatch_batch).max(1);
    if args.has_flag("no-coalesce") {
        cfg.coalesce_flush = false;
    }
}

/// Apply `--class NAME=WEIGHT:POLICY[:QUOTA],...` to a scheduler config.
/// Class N in the list gets `ClassId` N; a bad spec (including an unknown
/// policy token) exits 2 naming the flag and the offending token.
fn apply_classes(args: &Args, cfg: &mut SchedulerConfig) {
    if let Some(spec) = args.get_opt("class") {
        cfg.classes = JobClass::parse_list(spec).unwrap_or_else(|e| {
            eprintln!("--class: {e}");
            std::process::exit(2);
        });
    }
}

fn main() {
    let args = Args::parse();
    if args.has_flag("help") {
        usage();
        return;
    }
    match args.subcommand() {
        Some("run") => cmd_run(&args),
        Some("worker") => cmd_worker(&args),
        Some("des") => cmd_des(&args),
        Some("evac") => cmd_evac(&args),
        Some("info") => cmd_info(&args),
        Some("lint") => cmd_lint(&args),
        Some("check") => cmd_check(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}");
            }
            usage();
            std::process::exit(2);
        }
    }
}

fn cmd_run(args: &Args) {
    let Some(cmd) = args.positional().first().cloned() else {
        usage();
        std::process::exit(2);
    };
    let n = args.get_usize("n", 10);
    let np = args.get_usize("np", 4);
    let mut spec = JobSpec::command(cmd)
        .retries(args.get_u64("retries", 0) as u32)
        .priority(args.get_usize("priority", 0).min(u8::MAX as usize) as u8);
    if let Some(t) = args.get_opt("timeout") {
        spec = spec.timeout(t.parse().expect("--timeout: seconds"));
    }
    let mut cfg = SchedulerConfig {
        np,
        flush_interval_ms: 5,
        policy: parse_policy(args),
        ..Default::default()
    };
    apply_shape(args, &mut cfg);
    apply_reshape(args, &mut cfg);
    apply_classes(args, &mut cfg);
    apply_batching(args, &mut cfg);
    let n_classes = cfg.classes.len();
    let work = std::env::temp_dir().join(format!("caravan_run_{}", std::process::id()));
    let report = if let Some(listen) = args.get_opt("listen") {
        // Distributed mode: the tree lives in `caravan worker` processes;
        // this process runs only the engine + producer loop.
        let ep = Endpoint::parse(listen).unwrap_or_else(|e| {
            eprintln!("--listen: {e}");
            std::process::exit(2);
        });
        let listener = Listener::bind(&ep).unwrap_or_else(|e| {
            eprintln!("--listen {ep}: {e}");
            std::process::exit(2);
        });
        let workers = args.get_usize("workers", 1).max(1);
        caravan::info!("listening on {ep} for {workers} worker(s)");
        serve_scheduler(
            &cfg,
            Box::new(RepeatCmd { n, n_classes, spec }),
            &listener,
            &ServeOptions { workers, ..Default::default() },
        )
        .unwrap_or_else(|e| {
            eprintln!("serve: {e}");
            std::process::exit(1);
        })
    } else {
        run_scheduler(
            &cfg,
            Box::new(RepeatCmd { n, n_classes, spec }),
            Arc::new(CommandExecutor::new(&work)),
        )
    };
    let failures = report.results.iter().filter(|r| !r.ok()).count();
    let retried: u64 = report.node_stats.iter().map(|s| s.retried).sum();
    println!(
        "{} tasks, {} failures, {} retries, depth {} fanout {}{}, filling {:.1}%, wall {:.2}s",
        report.results.len(),
        failures,
        retried,
        report.depth,
        fanout_label(&report.fanout),
        if cfg.shape.is_auto() { " (auto)" } else { "" },
        report.rate(np) * 100.0,
        report.wall_secs
    );
    // Per-class dispatch summary: level-1 (root) nodes see every granted
    // task exactly once, so their per-class popped counts are the
    // dispatch totals. The CI multi-tenant smoke greps these lines.
    for (id, c) in cfg.classes.iter().enumerate() {
        let popped: u64 = report
            .node_stats
            .iter()
            .filter(|s| s.level == 1)
            .flat_map(|s| &s.class_stats)
            .filter(|cs| cs.class as usize == id)
            .map(|cs| cs.popped)
            .sum();
        println!(
            "  class {id} '{}': weight {}, policy {}, quota {}, {} dispatched",
            c.name,
            c.weight,
            policy_label(c.policy),
            c.quota.map_or_else(|| "-".to_string(), |q| q.to_string()),
            popped
        );
    }
    for ev in &report.reshapes {
        println!(
            "  reshape @{:.1}s: depth {} fanout {} -> depth {} fanout {} (rtt {:.2}ms, task {:.2}s)",
            ev.t,
            ev.from_depth,
            fanout_label(&ev.from_fanout),
            ev.to_depth,
            fanout_label(&ev.to_fanout),
            ev.cal.producer_rtt * 1e3,
            ev.cal.mean_task_s
        );
    }
    for s in report.node_stats.iter().filter(|s| s.wire_msgs_in + s.wire_msgs_out > 0) {
        println!(
            "  link slot {}: {} consumers, {} frames in / {} out, {} bytes in / {} out",
            s.node,
            s.subtree_consumers,
            s.wire_msgs_in,
            s.wire_msgs_out,
            s.wire_bytes_in,
            s.wire_bytes_out
        );
    }
    let _ = std::fs::remove_dir_all(&work);
    if failures > 0 {
        std::process::exit(1);
    }
}

fn cmd_worker(args: &Args) {
    let Some(addr) = args.positional().first().cloned() else {
        eprintln!("worker: missing <addr> (tcp:HOST:PORT or uds:/path.sock)");
        std::process::exit(2);
    };
    let ep = Endpoint::parse(&addr).unwrap_or_else(|e| {
        eprintln!("worker: {e}");
        std::process::exit(2);
    });
    let work = std::env::temp_dir().join(format!("caravan_worker_{}", std::process::id()));
    let exec = Arc::new(WorkerExecutor {
        sleep: SleepExecutor { time_scale: args.get_f64("time-scale", 1.0) },
        command: CommandExecutor::new(&work),
    });
    let outcome = connect_worker(&ep, exec, args.get_usize("np", 0));
    let _ = std::fs::remove_dir_all(&work);
    match outcome {
        Ok(r) => println!(
            "worker slot {}: {} consumers, {} results flushed, {} frames in / {} out ({} / {} bytes)",
            r.slot,
            r.np,
            r.tasks_run,
            r.link.msgs_in,
            r.link.msgs_out,
            r.link.bytes_in,
            r.link.bytes_out
        ),
        Err(e) => {
            eprintln!("worker: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_des(args: &Args) {
    let np = args.get_usize("np", 1024);
    let case = TestCase::parse(args.get_str("tc", "2")).expect("--tc 1|2|3");
    let n = args.get_usize("tasks-per-proc", 100) * np;
    let mut cfg = DesConfig::new(np);
    cfg.direct = args.has_flag("direct");
    apply_shape(args, &mut cfg.sched);
    apply_reshape(args, &mut cfg.sched);
    cfg.sched.steal = args.has_flag("steal") || args.has_flag("steal-round-robin");
    if args.has_flag("steal-round-robin") {
        cfg.sched.steal_policy = caravan::config::StealPolicy::RoundRobin;
    }
    cfg.sched.policy = parse_policy(args);
    apply_batching(args, &mut cfg.sched);
    if let Some(spec) = args.get_opt("link-latency") {
        cfg.lat.link_latency = spec
            .split(',')
            .map(|s| {
                s.trim().parse::<f64>().unwrap_or_else(|_| {
                    eprintln!("--link-latency: {s:?} is not a number of seconds");
                    std::process::exit(2);
                })
            })
            .collect();
    }
    // lint:allow(wall-clock) -- outermost CLI shell timing the whole DES run for display; never feeds results
    let t0 = std::time::Instant::now();
    let r = run_des(
        &cfg,
        Box::new(TestCaseEngine::new(case, n, args.get_u64("seed", 7))),
        Box::new(SleepDurations),
    );
    // Direct mode pins the topology (single-master ablation), so auto
    // shaping never runs there — don't claim it did.
    let shape_note = if cfg.sched.shape.is_auto() && !cfg.direct { " (auto)" } else { "" };
    println!(
        "{case:?} np={np} n={n} depth={} fanout={}{shape_note}: filling {:.2}%, makespan {:.0}s (virtual), {} events in {:.2}s wall",
        r.depth,
        fanout_label(&r.fanout),
        r.rate(np) * 100.0,
        r.makespan,
        r.events_processed,
        t0.elapsed().as_secs_f64()
    );
    for lf in &r.level_fill {
        println!(
            "  level {}: {} nodes, fill mean {:.2}% min {:.2}%",
            lf.level,
            lf.n_nodes,
            lf.mean_rate * 100.0,
            lf.min_rate * 100.0
        );
    }
    for ev in &r.reshapes {
        println!(
            "  reshape @{:.1}s: depth {} fanout {} -> depth {} fanout {} (rtt {:.2}ms, task {:.2}s)",
            ev.t,
            ev.from_depth,
            fanout_label(&ev.from_fanout),
            ev.to_depth,
            fanout_label(&ev.to_fanout),
            ev.cal.producer_rtt * 1e3,
            ev.cal.mean_task_s
        );
    }
    let stolen = r.tasks_stolen();
    if stolen > 0 {
        println!("  tasks stolen sideways: {stolen}");
    }
}

fn cmd_evac(args: &Args) {
    let variant = args.get_str("variant", "tiny").to_string();
    let params = match variant.as_str() {
        "tiny" => ScenarioParams::tiny(),
        "mini" => ScenarioParams::yodogawa_mini(),
        o => panic!("unknown variant {o:?}"),
    };
    let sc = Arc::new(build_scenario(&params, args.get_u64("scenario-seed", 1)));
    let backend: Arc<dyn SimBackend> = match args.get_str("backend", "rust") {
        "pjrt" => Arc::new(
            PjrtServer::start("artifacts".into(), &variant, sc.sim_arrays())
                .expect("run `make artifacts`"),
        ),
        _ => Arc::new(RustSimBackend::for_scenario(&sc)),
    };
    let ev = EvacEvaluator::new(Arc::clone(&sc), backend);
    let mut rng = Pcg64::new(args.get_u64("seed", 0));
    let genome: Vec<f64> = ev.bounds().iter().map(|&(lo, hi)| rng.range_f64(lo, hi)).collect();
    // lint:allow(wall-clock) -- outermost CLI shell timing one evaluation for display; never feeds results
    let t0 = std::time::Instant::now();
    let [f1, f2, f3] = ev.evaluate(&genome, args.get_u64("seed", 0));
    println!(
        "variant={variant} backend={}: f1={f1:.2} min, f2={f2:.3} nats, f3={f3:.0} persons ({:.0} ms)",
        args.get_str("backend", "rust"),
        t0.elapsed().as_secs_f64() * 1e3
    );
}

fn cmd_info(args: &Args) {
    let dir = args.get_str("artifacts", "artifacts").to_string();
    match ArtifactMeta::load(&dir) {
        Ok(meta) => {
            println!(
                "artifacts in {dir}/ (physics dt={} v_free={} rho_jam={}):",
                meta.physics.dt, meta.physics.v_free, meta.physics.rho_jam
            );
            for v in &meta.variants {
                println!(
                    "  {:>6}: {} (A={} L={} N={} S={} T={})",
                    v.name, v.file, v.a, v.l, v.n, v.s, v.t
                );
            }
        }
        Err(e) => println!("no artifacts: {e:#}"),
    }
    for (name, p) in [("tiny", ScenarioParams::tiny()), ("mini", ScenarioParams::yodogawa_mini())] {
        let sc = build_scenario(&p, 1);
        println!(
            "scenario {name}: {} nodes, {} links (pad {}), {} shelters, {} sub-areas, {} agents, pop {:.0}, cap {:.0}",
            sc.net.n_nodes(),
            sc.net.n_links(),
            sc.padded_links(),
            sc.shelters.len(),
            sc.subareas.len(),
            sc.n_agents,
            sc.total_population(),
            sc.total_capacity()
        );
    }
}

/// `caravan lint [--fix-hints] [PATHS..]` — run the determinism &
/// NaN-safety static-analysis pass (see `caravan::lint`). With no PATHS
/// it scans the crate's own sources relative to the current directory:
/// `rust/{src,tests,benches}` from the repo root, `{src,tests,benches}`
/// from inside `rust/`. Exit 0 on a clean tree, 1 on violations, 2 on
/// usage or IO errors — CI gates on this.
fn cmd_lint(args: &Args) {
    let mut fix_hints = args.has_flag("fix-hints");
    let mut roots: Vec<std::path::PathBuf> =
        args.positional().iter().map(std::path::PathBuf::from).collect();
    // `lint --fix-hints PATH`: the parser reads PATH as the flag's value;
    // reclaim it as a root so both argument orders work.
    if let Ok(Some(v)) = args.try_opt("fix-hints") {
        fix_hints = true;
        roots.push(std::path::PathBuf::from(v));
    }
    if roots.is_empty() {
        for cand in ["rust/src", "rust/tests", "rust/benches", "src", "tests", "benches"] {
            let p = std::path::PathBuf::from(cand);
            if p.is_dir() {
                roots.push(p);
            }
        }
    }
    if roots.is_empty() {
        eprintln!("caravan lint: no sources found here (pass PATHS explicitly)");
        std::process::exit(2);
    }
    match caravan::lint::lint_paths(&roots) {
        Err(e) => {
            eprintln!("caravan lint: {e}");
            std::process::exit(2);
        }
        Ok(report) => {
            for (path, v) in &report.violations {
                println!("{path}:{}: [{}] {}", v.line, v.rule, v.msg);
                if fix_hints {
                    println!("    hint: {}", v.hint);
                }
            }
            if report.is_clean() {
                println!("caravan lint: clean ({} files)", report.files_scanned);
            } else {
                println!(
                    "caravan lint: {} violation(s) in {} file(s) ({} files scanned)",
                    report.violations.len(),
                    report.files_with_violations(),
                    report.files_scanned
                );
                std::process::exit(1);
            }
        }
    }
}

/// Print one checker report, writing the minimized counterexample trace
/// to `trace_out` when given. Returns whether the run passed.
fn print_check_report(report: &caravan::check::CheckReport, trace_out: Option<&str>) -> bool {
    let phase = if report.exhausted { "exhaustive" } else { "state budget hit" };
    println!(
        "caravan check: scenario {} [faults {}] tasks={} — {} states ({phase}, \
         {} depth-pruned), {} fuzz schedule(s)",
        report.scenario,
        report.faults,
        report.n_tasks,
        report.states,
        report.depth_pruned,
        report.fuzz_schedules
    );
    let Some(cex) = &report.counterexample else {
        println!("caravan check: {}: all oracles held", report.scenario);
        return true;
    };
    println!("caravan check: VIOLATION [{}] {}", cex.violation.oracle, cex.violation.detail);
    println!(
        "caravan check: minimized schedule: {} event(s) (from {})",
        cex.events.len(),
        cex.original_len
    );
    let trace = report.counterexample_trace().unwrap_or_default();
    println!("--- replay trace (caravan check --replay FILE) ---");
    print!("{trace}");
    println!("--- end trace ---");
    if let Some(path) = trace_out {
        if let Err(e) = std::fs::write(path, &trace) {
            eprintln!("caravan check: --trace-out {path}: {e}");
            std::process::exit(2);
        }
        println!("caravan check: trace written to {path}");
    }
    false
}

/// `caravan check [--options]` — run the bounded protocol model checker
/// (see `caravan::check`): exhaustive DFS with partial-order reduction
/// over message interleavings, then seeded schedule fuzzing, with
/// invariant oracles after every step. Exit 0 when every oracle held,
/// 1 on a violation (printing a delta-debugged, replayable trace), 2 on
/// usage or IO errors — CI gates on this.
fn cmd_check(args: &Args) {
    use caravan::check::{replay_trace_text, run_check, scenarios, CheckConfig, FaultSet, SeededBug};

    let trace_out = args.get_opt("trace-out");

    if let Some(path) = args.get_opt("replay") {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("caravan check: --replay {path}: {e}");
            std::process::exit(2);
        });
        let report = replay_trace_text(&text).unwrap_or_else(|e| {
            eprintln!("caravan check: {e}");
            std::process::exit(2);
        });
        if !print_check_report(&report, trace_out) {
            std::process::exit(1);
        }
        return;
    }

    let defaults = CheckConfig::default();
    let scenario_arg = args.get_str("scenario", &defaults.scenario).to_string();
    let mut cfg = CheckConfig {
        n_tasks: args.get_usize("max-tasks", defaults.n_tasks),
        max_depth: args.get_usize("max-depth", defaults.max_depth),
        max_states: args.get_u64("max-states", defaults.max_states),
        seeds: args.get_u64("seeds", defaults.seeds),
        fuzz_steps: args.get_usize("fuzz-steps", defaults.fuzz_steps),
        ..defaults
    };
    if let Some(spec) = args.get_opt("faults") {
        cfg.faults = FaultSet::parse(spec).unwrap_or_else(|e| {
            eprintln!("caravan check: --faults: {e}");
            std::process::exit(2);
        });
    }
    if let Some(spec) = args.get_opt("inject-bug") {
        cfg.bug = Some(SeededBug::parse(spec).unwrap_or_else(|e| {
            eprintln!("caravan check: --inject-bug: {e}");
            std::process::exit(2);
        }));
    }

    let runs: Vec<(String, FaultSet)> = if scenario_arg == "all" {
        // Under `all`, the kill fault only applies to scenarios that can
        // model it — it is silently dropped elsewhere rather than erroring.
        scenarios()
            .iter()
            .map(|sc| {
                let mut f = cfg.faults;
                f.kill = f.kill && sc.kill_ok;
                (sc.name.to_string(), f)
            })
            .collect()
    } else {
        vec![(scenario_arg, cfg.faults)]
    };

    let mut all_passed = true;
    for (name, faults) in runs {
        let run_cfg = CheckConfig { scenario: name, faults, ..cfg.clone() };
        let report = run_check(&run_cfg).unwrap_or_else(|e| {
            eprintln!("caravan check: {e}");
            std::process::exit(2);
        });
        all_passed &= print_check_report(&report, trace_out);
    }
    if !all_passed {
        std::process::exit(1);
    }
}
