//! Length-prefixed binary codec for the scheduler's link protocol.
//!
//! Hand-rolled in the style of [`crate::util::json`] (the crate is fully
//! self-contained — no serde): every message is one *frame* of
//!
//! ```text
//! [u32 LE body length][u8 tag][tag-specific body]
//! ```
//!
//! All integers are little-endian; `f64` travels as `to_bits()` so every
//! value — including NaN payloads — round-trips **bit-identically**
//! (`encode(decode(b)) == b`). Strings are `u32` length + UTF-8 bytes;
//! `Option<T>` is a presence byte + `T`; `Vec<T>` is a `u32` count +
//! items. [`FrameReader`] reassembles frames from an arbitrarily
//! fragmented byte stream, so socket reads may split a frame anywhere.

use std::fmt;

use crate::config::{SchedPolicy, SchedulerConfig, StealPolicy, TreeShape};
use crate::tasklib::{Payload, TaskId, TaskResult, TaskSpec};

/// Version carried in [`WireMsg::Hello`]; a root refuses mismatches.
/// v2 added multi-tenancy: the class byte on every task and the class
/// registry in [`WireConfig`]. v3 added the batched hot path: the
/// coalesced [`WireMsg::Flush`] uplink frame and the
/// `dispatch_batch`/`coalesce_flush` knobs in [`WireConfig`].
pub const PROTO_VERSION: u32 = 3;

/// Upper bound on one frame's body, to fail fast on stream corruption
/// (a garbage length prefix) instead of attempting a huge allocation.
pub const MAX_FRAME: usize = 64 * 1024 * 1024;

/// Codec error: malformed frame, unknown tag, or truncated body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireError {
    /// Byte offset within the frame body where decoding failed.
    pub pos: usize,
    /// Human-readable cause.
    pub msg: String,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire decode error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for WireError {}

/// Everything that crosses a link between the producer side and a remote
/// worker's subtree. Downlink variants mirror the producer→buffer
/// messages of the in-process runtime; uplink variants mirror the
/// buffer→producer ones (a worker's gateway speaks for its whole local
/// subtree, so per-slot routing stays on the root side).
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Worker → root: first message after connect. `requested_np = 0`
    /// leaves the consumer-share decision to the root.
    Hello {
        /// Must equal [`PROTO_VERSION`].
        version: u32,
        /// Consumer processes the worker offers (0 = root decides).
        requested_np: u64,
    },
    /// Root → worker: handshake reply carrying the worker's root slot and
    /// its `SchedulerConfig` slice + level/fanout assignment.
    Welcome {
        /// The worker's slot among the producer's direct children.
        slot: u64,
        /// Configuration slice for the worker's local subtree.
        cfg: WireConfig,
    },
    /// Root → worker: task grant (the `Assign` hop over the wire).
    Assign(Vec<TaskSpec>),
    /// Root → worker: cancellation notice fanning into the subtree.
    Cancel {
        /// Task to drop (queued) or kill (running).
        id: TaskId,
    },
    /// Root → worker: drain the subtree and ack (drain-and-graft, and —
    /// implicitly — the failure path: a dead link is a recall that never
    /// acks).
    Recall,
    /// Root → worker: orderly teardown after quiescence.
    Shutdown,
    /// Worker → root: credit request from the gateway.
    Request {
        /// Tasks wanted to refill the subtree's credit.
        amount: u64,
    },
    /// Worker → root: batched results (consumer ranks already globalized).
    Results(Vec<TaskResult>),
    /// Worker → root: coalesced credit request + result flush — the
    /// gateway's `Flush` protocol step rides one frame instead of a
    /// `Request` plus a `Results` (consumer ranks already globalized).
    Flush {
        /// Tasks wanted to refill the subtree's credit.
        amount: u64,
        /// Completed results ascending with the request (possibly empty).
        results: Vec<TaskResult>,
    },
    /// Worker → root: queued tasks returned by a recall, stamps intact.
    Returned(Vec<TaskSpec>),
    /// Worker → root: the subtree is drained.
    RecallAck,
    /// Either direction: liveness heartbeat; carries no state.
    Ping,
}

/// The `SchedulerConfig` slice a [`WireMsg::Welcome`] hands a worker,
/// plus the worker's place in the global tree (level and first consumer
/// rank). Everything a worker needs to build its local subtree; nothing
/// it must not decide locally (shape is always concrete here — the root
/// resolves `Auto` before workers connect).
#[derive(Clone, Debug, PartialEq)]
pub struct WireConfig {
    /// Consumer processes assigned to this worker.
    pub np: u64,
    /// Consumers per leaf buffer within the worker's subtree.
    pub consumers_per_buffer: u64,
    /// Buffer levels of the worker's local tree.
    pub depth: u64,
    /// Per-level fanout plan (root-down, last element repeating).
    pub fanout: Vec<u64>,
    /// Sibling work stealing within the worker's subtree.
    pub steal: bool,
    /// Victim selection when `steal` is on.
    pub steal_policy: StealPolicy,
    /// Queue-ordering policy at every node.
    pub policy: SchedPolicy,
    /// Credit multiplier (tasks on hand per subtree consumer).
    pub credit_factor: u64,
    /// Result-store batch size before an upstream flush.
    pub flush_every: u64,
    /// Real seconds per virtual second for `Sleep` payloads.
    pub time_scale: f64,
    /// Buffer tick interval in milliseconds.
    pub flush_interval_ms: u64,
    /// Global tree level of the worker's gateway (1 = directly under the
    /// producer).
    pub level: u64,
    /// First global consumer rank of this worker's share; the gateway
    /// offsets local ranks by this before flushing results upstream.
    pub rank_base: u64,
    /// Tenant-class registry (empty = single-tenant): workers rebuild the
    /// same per-class lanes, weights and policies as the root's subtree.
    pub classes: Vec<crate::tenancy::JobClass>,
    /// Run-ahead dispatch depth per consumer (1 = per-task dispatch).
    pub dispatch_batch: u64,
    /// Merge same-step credit requests and result flushes into one
    /// upstream [`WireMsg::Flush`].
    pub coalesce_flush: bool,
}

impl WireConfig {
    /// Slice `cfg` for a worker owning `np` consumers starting at global
    /// rank `rank_base`, joining at tree `level`.
    pub fn from_scheduler(cfg: &SchedulerConfig, np: usize, level: usize, rank_base: usize) -> Self {
        WireConfig {
            np: np as u64,
            consumers_per_buffer: cfg.consumers_per_buffer as u64,
            depth: cfg.depth as u64,
            fanout: cfg.fanout.iter().map(|&f| f as u64).collect(),
            steal: cfg.steal,
            steal_policy: cfg.steal_policy,
            policy: cfg.policy,
            credit_factor: cfg.credit_factor as u64,
            flush_every: cfg.flush_every as u64,
            time_scale: cfg.time_scale,
            flush_interval_ms: cfg.flush_interval_ms,
            level: level as u64,
            rank_base: rank_base as u64,
            classes: cfg.classes.clone(),
            dispatch_batch: cfg.dispatch_batch as u64,
            coalesce_flush: cfg.coalesce_flush,
        }
    }

    /// Materialize the worker-local `SchedulerConfig` (always
    /// [`TreeShape::Manual`]: the shape decision was made root-side).
    pub fn to_scheduler(&self) -> SchedulerConfig {
        SchedulerConfig {
            np: self.np as usize,
            consumers_per_buffer: (self.consumers_per_buffer as usize).max(1),
            depth: (self.depth as usize).max(1),
            fanout: self.fanout.iter().map(|&f| f as usize).collect(),
            shape: TreeShape::Manual,
            reshape: None,
            steal: self.steal,
            steal_policy: self.steal_policy,
            policy: self.policy,
            credit_factor: (self.credit_factor as usize).max(1),
            flush_every: (self.flush_every as usize).max(1),
            time_scale: self.time_scale,
            flush_interval_ms: self.flush_interval_ms.max(1),
            classes: self.classes.clone(),
            dispatch_batch: (self.dispatch_batch as usize).max(1),
            coalesce_flush: self.coalesce_flush,
        }
    }
}

// --- frame tags ---
const TAG_HELLO: u8 = 0x01;
const TAG_WELCOME: u8 = 0x02;
const TAG_ASSIGN: u8 = 0x10;
const TAG_CANCEL: u8 = 0x11;
const TAG_RECALL: u8 = 0x12;
const TAG_SHUTDOWN: u8 = 0x13;
const TAG_REQUEST: u8 = 0x20;
const TAG_RESULTS: u8 = 0x21;
const TAG_RETURNED: u8 = 0x22;
const TAG_RECALL_ACK: u8 = 0x23;
const TAG_FLUSH: u8 = 0x24;
const TAG_PING: u8 = 0x30;

/// Encode `msg` as one complete frame (length prefix included).
pub fn encode(msg: &WireMsg) -> Vec<u8> {
    let mut e = Enc { out: vec![0, 0, 0, 0] }; // length patched below
    match msg {
        WireMsg::Hello { version, requested_np } => {
            e.u8(TAG_HELLO);
            e.u32(*version);
            e.u64(*requested_np);
        }
        WireMsg::Welcome { slot, cfg } => {
            e.u8(TAG_WELCOME);
            e.u64(*slot);
            e.config(cfg);
        }
        WireMsg::Assign(tasks) => {
            e.u8(TAG_ASSIGN);
            e.tasks(tasks);
        }
        WireMsg::Cancel { id } => {
            e.u8(TAG_CANCEL);
            e.u64(*id);
        }
        WireMsg::Recall => e.u8(TAG_RECALL),
        WireMsg::Shutdown => e.u8(TAG_SHUTDOWN),
        WireMsg::Request { amount } => {
            e.u8(TAG_REQUEST);
            e.u64(*amount);
        }
        WireMsg::Results(results) => {
            e.u8(TAG_RESULTS);
            e.u32(results.len() as u32);
            for r in results {
                e.result(r);
            }
        }
        WireMsg::Returned(tasks) => {
            e.u8(TAG_RETURNED);
            e.tasks(tasks);
        }
        WireMsg::Flush { amount, results } => {
            e.u8(TAG_FLUSH);
            e.u64(*amount);
            e.u32(results.len() as u32);
            for r in results {
                e.result(r);
            }
        }
        WireMsg::RecallAck => e.u8(TAG_RECALL_ACK),
        WireMsg::Ping => e.u8(TAG_PING),
    }
    let body_len = e.out.len().saturating_sub(4) as u32;
    if let Some(prefix) = e.out.get_mut(..4) {
        prefix.copy_from_slice(&body_len.to_le_bytes());
    }
    e.out
}

/// Decode one frame *body* (the bytes after the length prefix).
pub fn decode_body(body: &[u8]) -> Result<WireMsg, WireError> {
    let mut d = Dec { b: body, pos: 0 };
    let tag = d.u8()?;
    let msg = match tag {
        TAG_HELLO => WireMsg::Hello { version: d.u32()?, requested_np: d.u64()? },
        TAG_WELCOME => WireMsg::Welcome { slot: d.u64()?, cfg: d.config()? },
        TAG_ASSIGN => WireMsg::Assign(d.tasks()?),
        TAG_CANCEL => WireMsg::Cancel { id: d.u64()? },
        TAG_RECALL => WireMsg::Recall,
        TAG_SHUTDOWN => WireMsg::Shutdown,
        TAG_REQUEST => WireMsg::Request { amount: d.u64()? },
        TAG_RESULTS => {
            let n = d.count("results")?;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(d.result()?);
            }
            WireMsg::Results(out)
        }
        TAG_RETURNED => WireMsg::Returned(d.tasks()?),
        TAG_FLUSH => {
            let amount = d.u64()?;
            let n = d.count("flush results")?;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(d.result()?);
            }
            WireMsg::Flush { amount, results: out }
        }
        TAG_RECALL_ACK => WireMsg::RecallAck,
        TAG_PING => WireMsg::Ping,
        t => return Err(d.err(&format!("unknown message tag 0x{t:02x}"))),
    };
    if d.pos != body.len() {
        return Err(d.err("trailing bytes after message body"));
    }
    Ok(msg)
}

/// Reassembles frames from a fragmented byte stream: `push` whatever the
/// socket produced, then drain complete messages with `next`. Bytes may
/// arrive one at a time or many frames at once; framing is recovered
/// solely from the length prefixes.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    /// Fresh reader with an empty reassembly buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw bytes read from the stream.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (incomplete frame remainder).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Pop the next complete message, `Ok(None)` if the buffer holds only
    /// a partial frame. A malformed frame (oversized length prefix or
    /// undecodable body) is an error; the stream is unrecoverable past it.
    pub fn next_msg(&mut self) -> Result<Option<WireMsg>, WireError> {
        let Some(prefix) = self.buf.get(..4).and_then(|s| <[u8; 4]>::try_from(s).ok()) else {
            return Ok(None);
        };
        let len = u32::from_le_bytes(prefix) as usize;
        if len > MAX_FRAME {
            return Err(WireError { pos: 0, msg: format!("frame length {len} exceeds MAX_FRAME") });
        }
        // `len <= MAX_FRAME`, so `4 + len` cannot overflow.
        let Some(body) = self.buf.get(4..4 + len) else {
            return Ok(None);
        };
        let msg = decode_body(body)?;
        self.buf.drain(..4 + len);
        Ok(Some(msg))
    }
}

// --- primitive writers ---

struct Enc {
    out: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }

    fn bool(&mut self, v: bool) {
        self.out.push(u8::from(v));
    }

    fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        // Bit pattern, not value: NaNs survive the round trip.
        self.u64(v.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.out.extend_from_slice(s.as_bytes());
    }

    fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
        }
    }

    fn opt_str(&mut self, v: &Option<String>) {
        match v {
            None => self.u8(0),
            Some(s) => {
                self.u8(1);
                self.str(s);
            }
        }
    }

    fn vec_f64(&mut self, xs: &[f64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f64(x);
        }
    }

    fn payload(&mut self, p: &Payload) {
        match p {
            Payload::Sleep { seconds } => {
                self.u8(0);
                self.f64(*seconds);
            }
            Payload::Command { cmdline } => {
                self.u8(1);
                self.str(cmdline);
            }
            Payload::Eval { input, seed } => {
                self.u8(2);
                self.vec_f64(input);
                self.u64(*seed);
            }
        }
    }

    fn task(&mut self, t: &TaskSpec) {
        self.u64(t.id);
        self.payload(&t.payload);
        self.u8(t.priority);
        self.u32(t.max_retries);
        self.u32(t.attempt);
        self.opt_f64(t.timeout_s);
        self.opt_str(&t.tag);
        self.opt_f64(t.enqueued_t);
        self.u8(t.class);
    }

    fn tasks(&mut self, ts: &[TaskSpec]) {
        self.u32(ts.len() as u32);
        for t in ts {
            self.task(t);
        }
    }

    fn result(&mut self, r: &TaskResult) {
        self.u64(r.id);
        self.u64(r.consumer as u64);
        self.vec_f64(&r.results);
        self.f64(r.begin);
        self.f64(r.finish);
        self.i32(r.rc);
        self.u32(r.attempt);
        self.bool(r.timed_out);
    }

    fn config(&mut self, c: &WireConfig) {
        self.u64(c.np);
        self.u64(c.consumers_per_buffer);
        self.u64(c.depth);
        self.u32(c.fanout.len() as u32);
        for &f in &c.fanout {
            self.u64(f);
        }
        self.bool(c.steal);
        self.u8(match c.steal_policy {
            StealPolicy::RoundRobin => 0,
            StealPolicy::DeepestQueue => 1,
        });
        match c.policy {
            SchedPolicy::Strict => self.u8(0),
            SchedPolicy::Deadline => self.u8(1),
            SchedPolicy::Aging { step } => {
                self.u8(2);
                self.f64(step);
            }
        }
        self.u64(c.credit_factor);
        self.u64(c.flush_every);
        self.f64(c.time_scale);
        self.u64(c.flush_interval_ms);
        self.u64(c.level);
        self.u64(c.rank_base);
        self.u32(c.classes.len() as u32);
        for class in &c.classes {
            self.str(&class.name);
            self.u32(class.weight);
            match class.policy {
                SchedPolicy::Strict => self.u8(0),
                SchedPolicy::Deadline => self.u8(1),
                SchedPolicy::Aging { step } => {
                    self.u8(2);
                    self.f64(step);
                }
            }
            match class.quota {
                None => self.u8(0),
                Some(q) => {
                    self.u8(1);
                    self.u64(q as u64);
                }
            }
        }
        self.u64(c.dispatch_batch);
        self.bool(c.coalesce_flush);
    }
}

// --- primitive readers ---

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn err(&self, msg: &str) -> WireError {
        WireError { pos: self.pos, msg: msg.to_string() }
    }

    fn remaining(&self) -> usize {
        self.b.len().saturating_sub(self.pos)
    }

    /// Read a `u32` element count and reject it when it exceeds the
    /// bytes left in the body: every element encodes to at least one
    /// byte, so a larger count is a corrupt (or hostile) length bomb —
    /// failing here keeps allocations bounded by the input size.
    fn count(&mut self, what: &str) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        if n > self.remaining() {
            return Err(self.err(&format!(
                "{what} count {n} exceeds the {} bytes left in the body",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let s = self
            .pos
            .checked_add(n)
            .filter(|&end| end <= self.b.len())
            .and_then(|end| self.b.get(self.pos..end));
        let Some(s) = s else {
            return Err(self.err("truncated message body"));
        };
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        match self.take(1)?.first() {
            Some(&v) => Ok(v),
            None => Err(self.err("truncated message body")),
        }
    }

    fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(self.err(&format!("bad bool byte {v}"))),
        }
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        let arr: [u8; 4] = s.try_into().map_err(|_| self.err("truncated message body"))?;
        Ok(u32::from_le_bytes(arr))
    }

    fn i32(&mut self) -> Result<i32, WireError> {
        let s = self.take(4)?;
        let arr: [u8; 4] = s.try_into().map_err(|_| self.err("truncated message body"))?;
        Ok(i32::from_le_bytes(arr))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        let arr: [u8; 8] = s.try_into().map_err(|_| self.err("truncated message body"))?;
        Ok(u64::from_le_bytes(arr))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| self.err("invalid utf-8 in string"))
    }

    fn opt_f64(&mut self) -> Result<Option<f64>, WireError> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    fn opt_str(&mut self) -> Result<Option<String>, WireError> {
        Ok(if self.bool()? { Some(self.str()?) } else { None })
    }

    fn vec_f64(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.count("f64 vector")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    fn payload(&mut self) -> Result<Payload, WireError> {
        match self.u8()? {
            0 => Ok(Payload::Sleep { seconds: self.f64()? }),
            1 => Ok(Payload::Command { cmdline: self.str()? }),
            2 => Ok(Payload::Eval { input: self.vec_f64()?, seed: self.u64()? }),
            t => Err(self.err(&format!("unknown payload tag {t}"))),
        }
    }

    fn task(&mut self) -> Result<TaskSpec, WireError> {
        Ok(TaskSpec {
            id: self.u64()?,
            payload: self.payload()?,
            priority: self.u8()?,
            max_retries: self.u32()?,
            attempt: self.u32()?,
            timeout_s: self.opt_f64()?,
            tag: self.opt_str()?,
            enqueued_t: self.opt_f64()?,
            class: self.u8()?,
        })
    }

    fn tasks(&mut self) -> Result<Vec<TaskSpec>, WireError> {
        let n = self.count("task list")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.task()?);
        }
        Ok(out)
    }

    fn result(&mut self) -> Result<TaskResult, WireError> {
        Ok(TaskResult {
            id: self.u64()?,
            consumer: self.u64()? as usize,
            results: self.vec_f64()?,
            begin: self.f64()?,
            finish: self.f64()?,
            rc: self.i32()?,
            attempt: self.u32()?,
            timed_out: self.bool()?,
        })
    }

    fn config(&mut self) -> Result<WireConfig, WireError> {
        let np = self.u64()?;
        let consumers_per_buffer = self.u64()?;
        let depth = self.u64()?;
        let n_fans = self.count("fanout list")?;
        let mut fanout = Vec::with_capacity(n_fans);
        for _ in 0..n_fans {
            fanout.push(self.u64()?);
        }
        let steal = self.bool()?;
        let steal_policy = match self.u8()? {
            0 => StealPolicy::RoundRobin,
            1 => StealPolicy::DeepestQueue,
            t => return Err(self.err(&format!("unknown steal policy tag {t}"))),
        };
        let policy = match self.u8()? {
            0 => SchedPolicy::Strict,
            1 => SchedPolicy::Deadline,
            2 => SchedPolicy::Aging { step: self.f64()? },
            t => return Err(self.err(&format!("unknown sched policy tag {t}"))),
        };
        let credit_factor = self.u64()?;
        let flush_every = self.u64()?;
        let time_scale = self.f64()?;
        let flush_interval_ms = self.u64()?;
        let level = self.u64()?;
        let rank_base = self.u64()?;
        let n_classes = self.count("class registry")?;
        let mut classes = Vec::with_capacity(n_classes);
        for _ in 0..n_classes {
            let name = self.str()?;
            let weight = self.u32()?;
            let policy = match self.u8()? {
                0 => SchedPolicy::Strict,
                1 => SchedPolicy::Deadline,
                2 => SchedPolicy::Aging { step: self.f64()? },
                t => return Err(self.err(&format!("unknown class policy tag {t}"))),
            };
            let quota = if self.bool()? { Some(self.u64()? as usize) } else { None };
            classes.push(crate::tenancy::JobClass { name, policy, weight, quota });
        }
        let dispatch_batch = self.u64()?;
        let coalesce_flush = self.bool()?;
        Ok(WireConfig {
            np,
            consumers_per_buffer,
            depth,
            fanout,
            steal,
            steal_policy,
            policy,
            credit_factor,
            flush_every,
            time_scale,
            flush_interval_ms,
            level,
            rank_base,
            classes,
            dispatch_batch,
            coalesce_flush,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &WireMsg) {
        let bytes = encode(msg);
        let mut r = FrameReader::new();
        r.push(&bytes);
        let got = r.next_msg().expect("decode").expect("complete frame");
        assert_eq!(&got, msg);
        assert_eq!(r.buffered(), 0, "no leftover bytes");
        // Bit-identity: re-encoding the decoded message reproduces the
        // exact byte stream.
        assert_eq!(encode(&got), bytes);
    }

    fn spec(id: u64, payload: Payload) -> TaskSpec {
        TaskSpec {
            id,
            payload,
            priority: 3,
            max_retries: 2,
            attempt: 1,
            timeout_s: Some(12.5),
            tag: Some("band-a".to_string()),
            enqueued_t: Some(0.25),
            class: 1,
        }
    }

    #[test]
    fn every_variant_roundtrips() {
        let cfg = WireConfig::from_scheduler(&SchedulerConfig::default(), 4, 1, 12);
        let msgs = vec![
            WireMsg::Hello { version: PROTO_VERSION, requested_np: 7 },
            WireMsg::Welcome { slot: 3, cfg },
            WireMsg::Assign(vec![
                spec(1, Payload::Sleep { seconds: 1.5 }),
                spec(2, Payload::Command { cmdline: "sh -c 'echo π > _results.txt'".into() }),
                spec(3, Payload::Eval { input: vec![0.1, -0.2, f64::INFINITY], seed: 42 }),
                TaskSpec::new(4, Payload::Sleep { seconds: 0.0 }),
            ]),
            WireMsg::Cancel { id: u64::MAX },
            WireMsg::Recall,
            WireMsg::Shutdown,
            WireMsg::Request { amount: 384 },
            WireMsg::Results(vec![
                TaskResult {
                    id: 9,
                    consumer: 1023,
                    results: vec![1.0, f64::NAN, -0.0],
                    begin: 0.5,
                    finish: 1.25,
                    rc: -7,
                    attempt: 2,
                    timed_out: true,
                },
                TaskResult {
                    id: 10,
                    consumer: usize::MAX,
                    results: vec![],
                    begin: 0.0,
                    finish: 0.0,
                    rc: crate::tasklib::RC_CANCELLED,
                    attempt: 0,
                    timed_out: false,
                },
            ]),
            WireMsg::Returned(vec![spec(5, Payload::Sleep { seconds: 2.0 })]),
            WireMsg::Flush {
                amount: 96,
                results: vec![
                    TaskResult {
                        id: 11,
                        consumer: 7,
                        results: vec![f64::NAN, 3.5],
                        begin: 2.0,
                        finish: 2.5,
                        rc: 0,
                        attempt: 1,
                        timed_out: false,
                    },
                    TaskResult {
                        id: 12,
                        consumer: usize::MAX,
                        results: vec![],
                        begin: 0.0,
                        finish: 0.0,
                        rc: crate::tasklib::RC_CANCELLED,
                        attempt: 0,
                        timed_out: false,
                    },
                ],
            },
            WireMsg::Flush { amount: 0, results: vec![] },
            WireMsg::RecallAck,
            WireMsg::Ping,
        ];
        for m in &msgs {
            roundtrip(m);
        }
    }

    #[test]
    fn nan_bit_patterns_survive() {
        // A quiet NaN with a payload: value comparison can't see it, the
        // bit pattern can.
        let weird = f64::from_bits(0x7ff8_0000_dead_beef);
        let msg = WireMsg::Results(vec![TaskResult {
            id: 0,
            consumer: 0,
            results: vec![weird],
            begin: weird,
            finish: f64::NEG_INFINITY,
            rc: 0,
            attempt: 0,
            timed_out: false,
        }]);
        let bytes = encode(&msg);
        let mut r = FrameReader::new();
        r.push(&bytes);
        let got = r.next_msg().unwrap().unwrap();
        match got {
            WireMsg::Results(rs) => {
                assert_eq!(rs[0].results[0].to_bits(), weird.to_bits());
                assert_eq!(rs[0].begin.to_bits(), weird.to_bits());
            }
            other => panic!("wrong variant {other:?}"),
        }
        assert_eq!(encode(&WireMsg::Ping).len(), 5, "ping is 4-byte prefix + tag");
    }

    #[test]
    fn codec_roundtrip_property() {
        // Random TaskSpecs (random payload kind, options, float bits)
        // through Assign/Returned/Results frames: decode must reproduce
        // the message and re-encode the identical bytes.
        use crate::testutil::{check, u64_in};
        check("wire codec round-trips random tasks bit-identically", u64_in(0..u64::MAX), |&s| {
            // Derive all fields from the seed via splitmix-style mixing so
            // the case is a pure function of the strategy draw.
            let mut x = s;
            let mut next = move || {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                x ^ (x >> 33)
            };
            let payload = match next() % 3 {
                0 => Payload::Sleep { seconds: f64::from_bits(next()) },
                1 => Payload::Command { cmdline: format!("cmd-{}", next() % 1000) },
                _ => Payload::Eval {
                    input: (0..(next() % 5)).map(|_| f64::from_bits(next())).collect(),
                    seed: next(),
                },
            };
            let t = TaskSpec {
                id: next(),
                payload,
                priority: (next() % 256) as u8,
                max_retries: (next() % 10) as u32,
                attempt: (next() % 10) as u32,
                timeout_s: if next() % 2 == 0 { Some(f64::from_bits(next())) } else { None },
                tag: if next() % 2 == 0 { Some(format!("t{}", next() % 100)) } else { None },
                enqueued_t: if next() % 2 == 0 { Some(f64::from_bits(next())) } else { None },
                class: (next() % 256) as u8,
            };
            let r = TaskResult {
                id: next(),
                consumer: (next() % (1 << 32)) as usize,
                results: (0..(next() % 4)).map(|_| f64::from_bits(next())).collect(),
                begin: f64::from_bits(next()),
                finish: f64::from_bits(next()),
                rc: next() as i32,
                attempt: (next() % 8) as u32,
                timed_out: next() % 2 == 0,
            };
            for msg in [
                WireMsg::Assign(vec![t.clone()]),
                WireMsg::Returned(vec![t.clone()]),
                WireMsg::Results(vec![r]),
            ] {
                let bytes = encode(&msg);
                let got = match decode_body(&bytes[4..]) {
                    Ok(m) => m,
                    Err(_) => return false,
                };
                if encode(&got) != bytes {
                    return false;
                }
                // Float fields compare by bits via re-encoding; the
                // structural equality below additionally covers the
                // non-float fields (NaN != NaN, so only check when the
                // encoding has no NaN — bit identity above is the real
                // oracle).
            }
            true
        });
    }

    #[test]
    fn split_reads_reassemble_frames() {
        // Three frames, fed one byte at a time: the reader must emit
        // exactly the three messages, in order, regardless of fragment
        // boundaries.
        let msgs = vec![
            WireMsg::Request { amount: 17 },
            WireMsg::Assign(vec![spec(8, Payload::Eval { input: vec![1.0, 2.0], seed: 5 })]),
            WireMsg::RecallAck,
        ];
        let mut stream = Vec::new();
        for m in &msgs {
            stream.extend_from_slice(&encode(m));
        }
        for chunk in [1usize, 2, 3, 7] {
            let mut r = FrameReader::new();
            let mut got = Vec::new();
            for piece in stream.chunks(chunk) {
                r.push(piece);
                while let Some(m) = r.next_msg().expect("decode") {
                    got.push(m);
                }
            }
            assert_eq!(got, msgs, "chunk size {chunk}");
            assert_eq!(r.buffered(), 0);
        }
    }

    #[test]
    fn partial_frame_is_not_an_error() {
        let bytes = encode(&WireMsg::Cancel { id: 3 });
        let mut r = FrameReader::new();
        r.push(&bytes[..bytes.len() - 1]);
        assert_eq!(r.next_msg().expect("partial is Ok"), None);
        r.push(&bytes[bytes.len() - 1..]);
        assert_eq!(r.next_msg().unwrap(), Some(WireMsg::Cancel { id: 3 }));
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        // Oversized length prefix.
        let mut r = FrameReader::new();
        r.push(&(u32::MAX).to_le_bytes());
        assert!(r.next_msg().is_err());
        // Unknown tag.
        let mut r = FrameReader::new();
        r.push(&1u32.to_le_bytes());
        r.push(&[0xEE]);
        assert!(r.next_msg().is_err());
        // Truncated body (length lies short): Cancel needs 9 body bytes.
        let good = encode(&WireMsg::Cancel { id: 3 });
        let mut bad = good.clone();
        bad[..4].copy_from_slice(&5u32.to_le_bytes());
        let mut r = FrameReader::new();
        r.push(&bad[..9]);
        assert!(r.next_msg().is_err());
        // Trailing bytes (length lies long) — need the full long frame
        // buffered before decode fires.
        let mut long = good;
        long[..4].copy_from_slice(&10u32.to_le_bytes());
        long.push(0);
        let mut r = FrameReader::new();
        r.push(&long);
        assert!(r.next_msg().is_err());
    }

    #[test]
    fn decoder_survives_truncation_corruption_and_count_bombs() {
        // Adversarial-input property: for a corpus covering every variant,
        // (a) every strict prefix of the body decodes to Err — the codec
        // reads exactly the declared structure and rejects both missing
        // and trailing bytes, so no truncation point can succeed;
        // (b) flipping any single body byte returns Ok or Err, never a
        // panic or a huge allocation;
        // (c) u32::MAX stamped over any 4-byte window never panics or
        // over-allocates, and stamped over an *element-count* field is
        // rejected outright (the length-bomb shape).
        let cfg = WireConfig::from_scheduler(&SchedulerConfig::default(), 4, 1, 12);
        let corpus = vec![
            WireMsg::Hello { version: PROTO_VERSION, requested_np: 7 },
            WireMsg::Welcome { slot: 3, cfg },
            WireMsg::Assign(vec![
                spec(1, Payload::Sleep { seconds: 1.5 }),
                spec(2, Payload::Command { cmdline: "echo hi".into() }),
                spec(3, Payload::Eval { input: vec![0.5, -0.25], seed: 9 }),
            ]),
            WireMsg::Cancel { id: 11 },
            WireMsg::Recall,
            WireMsg::Shutdown,
            WireMsg::Request { amount: 384 },
            WireMsg::Results(vec![TaskResult {
                id: 9,
                consumer: 3,
                results: vec![1.0, -2.5],
                begin: 0.5,
                finish: 1.25,
                rc: 0,
                attempt: 1,
                timed_out: false,
            }]),
            WireMsg::Returned(vec![spec(5, Payload::Sleep { seconds: 2.0 })]),
            WireMsg::Flush {
                amount: 48,
                results: vec![TaskResult {
                    id: 21,
                    consumer: 5,
                    results: vec![0.25],
                    begin: 1.0,
                    finish: 1.5,
                    rc: 0,
                    attempt: 1,
                    timed_out: false,
                }],
            },
            WireMsg::RecallAck,
            WireMsg::Ping,
        ];
        for msg in &corpus {
            let frame = encode(msg);
            let body = &frame[4..];
            // (a) truncation at every point strictly inside the body.
            for cut in 0..body.len() {
                assert!(
                    decode_body(&body[..cut]).is_err(),
                    "{msg:?}: truncated body of {cut}/{} bytes must not decode",
                    body.len()
                );
            }
            // (b) single-byte corruption: any outcome but a panic. When it
            // decodes, the result must re-encode without panicking too.
            for i in 0..body.len() {
                let mut bad = body.to_vec();
                bad[i] ^= 0xFF;
                if let Ok(m) = decode_body(&bad) {
                    let _ = encode(&m);
                }
            }
            // (c) u32::MAX stamped over every 4-byte window — when it
            // lands on a count or length field this is the length-bomb
            // shape (a claim of ~4 billion elements backed by a tiny
            // body). Any window may instead hit a plain integer field and
            // decode fine; the property is that *no* window panics or
            // triggers a huge allocation — the `count`/`take` guards
            // bound every allocation by the bytes actually present.
            for i in 0..body.len().saturating_sub(3) {
                let mut bomb = body.to_vec();
                bomb[i..i + 4].copy_from_slice(&u32::MAX.to_le_bytes());
                if let Ok(m) = decode_body(&bomb) {
                    let _ = encode(&m);
                }
            }
        }
        // Targeted count bombs: the element count of every vec-carrying
        // top-level message sits at body bytes 1..5 (right after the
        // tag). A bombed count MUST be rejected — each element encodes at
        // least one byte, so the claim can never fit the body.
        for msg in [
            WireMsg::Assign(vec![spec(1, Payload::Sleep { seconds: 0.5 })]),
            WireMsg::Returned(vec![spec(2, Payload::Sleep { seconds: 0.5 })]),
            WireMsg::Results(vec![]),
        ] {
            let frame = encode(&msg);
            let mut bomb = frame[4..].to_vec();
            bomb[1..5].copy_from_slice(&u32::MAX.to_le_bytes());
            assert!(
                decode_body(&bomb).is_err(),
                "{msg:?}: count bomb in the element-count field must be rejected"
            );
        }
        // Flush carries its result count at body bytes 9..13 (after the
        // tag byte and the u64 credit amount), so the 1..5 sweep above
        // misses it — bomb that field directly.
        {
            let frame = encode(&WireMsg::Flush { amount: 7, results: vec![] });
            let mut bomb = frame[4..].to_vec();
            assert_eq!(bomb.len(), 13, "tag + u64 amount + u32 count");
            bomb[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
            assert!(
                decode_body(&bomb).is_err(),
                "Flush: count bomb in the result-count field must be rejected"
            );
        }
        // The FrameReader path: a length prefix just over MAX_FRAME is
        // rejected without buffering gigabytes.
        let mut r = FrameReader::new();
        r.push(&((MAX_FRAME as u32) + 1).to_le_bytes());
        assert!(r.next_msg().is_err());
    }

    #[test]
    fn wire_config_roundtrips_to_scheduler() {
        use crate::tenancy::JobClass;
        let cfg = SchedulerConfig {
            steal: true,
            policy: SchedPolicy::Aging { step: 7.5 },
            fanout: vec![4, 8],
            classes: vec![
                JobClass::new("steady", 2).quota(64),
                JobClass::new("burst", 4).policy(SchedPolicy::Deadline),
            ],
            dispatch_batch: 8,
            coalesce_flush: true,
            ..Default::default()
        };
        let w = WireConfig::from_scheduler(&cfg, 96, 1, 384);
        // The registry survives the binary codec bit-identically...
        roundtrip(&WireMsg::Welcome { slot: 0, cfg: w.clone() });
        // ...and the worker-side materialization.
        let back = w.to_scheduler();
        assert_eq!(back.np, 96);
        assert_eq!(back.fanout, vec![4, 8]);
        assert_eq!(back.policy, SchedPolicy::Aging { step: 7.5 });
        assert!(back.steal);
        assert_eq!(back.classes, cfg.classes);
        assert_eq!(back.dispatch_batch, 8, "v3 batching knob survives the wire");
        assert!(back.coalesce_flush);
        assert_eq!(w.rank_base, 384);
        assert_eq!(w.level, 1);
    }
}
