//! Transport boundary behind the pure protocol layer.
//!
//! [`crate::scheduler::protocol`] is already a pure message-passing state
//! machine; this module carries those messages across a *link*: the
//! in-process channel pair the threaded runtime always used, or a real
//! byte stream (TCP / Unix-domain socket) to a [`crate::scheduler::net`]
//! worker process. One [`Transport`] trait covers all three, so the
//! distributed serve loop and its tests are transport-agnostic.
//!
//! Framing lives in [`wire`]: length-prefixed binary frames, hand-rolled
//! (no serde). Socket transports count frames and encoded bytes per
//! direction ([`LinkStats`]); those counters surface as the per-edge
//! `wire_*` fields of [`crate::scheduler::NodeStats`].
//!
//! Failure model: a link never *recovers*. A read timeout past the
//! liveness budget, a peer close, or a codec error all surface as
//! [`TransportError::Closed`]-class failures that the serve loop treats
//! as "a recall that never acks" — the dead child's outstanding tasks are
//! re-granted elsewhere (see `scheduler::net`).

pub mod wire;

use std::fmt;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use wire::{encode, FrameReader, WireMsg};

/// Why a [`Transport`] call failed.
#[derive(Debug)]
pub enum TransportError {
    /// No message within the timeout; the link may still be healthy.
    Timeout,
    /// The link is done: peer closed, I/O error, or a codec failure
    /// (framing is unrecoverable past a corrupt frame).
    Closed(String),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Timeout => write!(f, "transport recv timed out"),
            TransportError::Closed(why) => write!(f, "transport closed: {why}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// Per-link traffic counters (cumulative, both halves of a split share
/// them). In-process channels move no bytes, so their byte counters stay
/// zero while the message counters still tick.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Messages received on this link.
    pub msgs_in: u64,
    /// Messages sent on this link.
    pub msgs_out: u64,
    /// Encoded frame bytes received (0 for in-process links).
    pub bytes_in: u64,
    /// Encoded frame bytes sent (0 for in-process links).
    pub bytes_out: u64,
}

#[derive(Default)]
struct Counters {
    msgs_in: AtomicU64,
    msgs_out: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> LinkStats {
        LinkStats {
            msgs_in: self.msgs_in.load(Ordering::Relaxed),
            msgs_out: self.msgs_out.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// One bidirectional message link. Implementations: the in-process
/// [`ChannelTransport`] and the TCP / Unix-domain [`SocketTransport`].
pub trait Transport: Send {
    /// Send one message; blocks until handed to the OS (sockets) or the
    /// peer's queue (channels).
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError>;

    /// Receive the next message, waiting at most `timeout`.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<WireMsg, TransportError>;

    /// Cumulative traffic counters for this link (shared across split
    /// halves).
    fn stats(&self) -> LinkStats;

    /// Split into `(sender, receiver)` halves usable from different
    /// threads — the serve loop writes grants while a reader thread
    /// blocks on the link. Calling the missing direction on a half
    /// returns [`TransportError::Closed`].
    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>), TransportError>;
}

/// In-process [`Transport`] over a pair of mpsc channels — the link the
/// threaded runtime always was, now behind the trait so the distributed
/// serve loop can be exercised without sockets.
pub struct ChannelTransport {
    tx: Option<Sender<WireMsg>>,
    rx: Option<Receiver<WireMsg>>,
    counters: Arc<Counters>,
}

impl ChannelTransport {
    /// A connected pair: what one end sends, the other receives.
    pub fn pair() -> (ChannelTransport, ChannelTransport) {
        let (a_tx, a_rx) = channel::<WireMsg>();
        let (b_tx, b_rx) = channel::<WireMsg>();
        (
            ChannelTransport {
                tx: Some(a_tx),
                rx: Some(b_rx),
                counters: Arc::new(Counters::default()),
            },
            ChannelTransport {
                tx: Some(b_tx),
                rx: Some(a_rx),
                counters: Arc::new(Counters::default()),
            },
        )
    }
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| TransportError::Closed("send on receiver half".into()))?;
        tx.send(msg.clone()).map_err(|_| TransportError::Closed("peer dropped".into()))?;
        self.counters.msgs_out.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<WireMsg, TransportError> {
        let rx = self
            .rx
            .as_ref()
            .ok_or_else(|| TransportError::Closed("recv on sender half".into()))?;
        match rx.recv_timeout(timeout) {
            Ok(m) => {
                self.counters.msgs_in.fetch_add(1, Ordering::Relaxed);
                Ok(m)
            }
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout),
            Err(RecvTimeoutError::Disconnected) => {
                Err(TransportError::Closed("peer dropped".into()))
            }
        }
    }

    fn stats(&self) -> LinkStats {
        self.counters.snapshot()
    }

    fn split(
        mut self: Box<Self>,
    ) -> Result<(Box<dyn Transport>, Box<dyn Transport>), TransportError> {
        let counters = Arc::clone(&self.counters);
        let sender = ChannelTransport { tx: self.tx.take(), rx: None, counters };
        Ok((Box::new(sender), self))
    }
}

enum Stream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> io::Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            Stream::Uds(s) => Stream::Uds(s.try_clone()?),
        })
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            Stream::Uds(s) => s.set_read_timeout(d),
        }
    }

    fn read_some(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Uds(s) => s.read(buf),
        }
    }

    fn write_all_bytes(&mut self, bytes: &[u8]) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.write_all(bytes),
            Stream::Uds(s) => s.write_all(bytes),
        }
    }
}

/// [`Transport`] over a byte stream (TCP or Unix-domain socket), with
/// [`wire`] framing and per-direction byte/message counters.
pub struct SocketTransport {
    stream: Stream,
    reader: FrameReader,
    counters: Arc<Counters>,
}

impl SocketTransport {
    /// Wrap a connected TCP stream.
    pub fn tcp(stream: TcpStream) -> Self {
        let _ = stream.set_nodelay(true); // grants are latency-sensitive
        SocketTransport {
            stream: Stream::Tcp(stream),
            reader: FrameReader::new(),
            counters: Arc::new(Counters::default()),
        }
    }

    /// Wrap a connected Unix-domain stream.
    pub fn uds(stream: UnixStream) -> Self {
        SocketTransport {
            stream: Stream::Uds(stream),
            reader: FrameReader::new(),
            counters: Arc::new(Counters::default()),
        }
    }
}

impl Transport for SocketTransport {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        let bytes = encode(msg);
        self.stream
            .write_all_bytes(&bytes)
            .map_err(|e| TransportError::Closed(e.to_string()))?;
        self.counters.msgs_out.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_out.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<WireMsg, TransportError> {
        // lint:allow(wall-clock) -- socket read deadline: real I/O budget, not simulation time
        let deadline = Instant::now() + timeout;
        let mut buf = [0u8; 8192];
        loop {
            if let Some(msg) =
                self.reader.next_msg().map_err(|e| TransportError::Closed(e.to_string()))?
            {
                self.counters.msgs_in.fetch_add(1, Ordering::Relaxed);
                return Ok(msg);
            }
            // lint:allow(wall-clock) -- socket read deadline: real I/O budget, not simulation time
            let now = Instant::now();
            if now >= deadline {
                return Err(TransportError::Timeout);
            }
            self.stream
                .set_read_timeout(Some(deadline - now))
                .map_err(|e| TransportError::Closed(e.to_string()))?;
            match self.stream.read_some(&mut buf) {
                Ok(0) => return Err(TransportError::Closed("peer closed".into())),
                Ok(n) => {
                    self.reader.push(buf.get(..n).unwrap_or(&[]));
                    self.counters.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                }
                Err(e)
                    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
                {
                    return Err(TransportError::Timeout)
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(TransportError::Closed(e.to_string())),
            }
        }
    }

    fn stats(&self) -> LinkStats {
        self.counters.snapshot()
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>), TransportError> {
        let writer = self.stream.try_clone().map_err(|e| TransportError::Closed(e.to_string()))?;
        let sender = SocketTransport {
            stream: writer,
            reader: FrameReader::new(),
            counters: Arc::clone(&self.counters),
        };
        Ok((Box::new(sender), self))
    }
}

/// A parsed link address: `tcp:HOST:PORT` or `uds:/path/to.sock`. Bare
/// spellings are inferred — a `/` means a socket path, a `:` means
/// host:port.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP `HOST:PORT`.
    Tcp(String),
    /// Unix-domain socket path.
    Uds(PathBuf),
}

impl Endpoint {
    /// Parse an address spelling; errors name the expected forms.
    pub fn parse(s: &str) -> Result<Endpoint, String> {
        if let Some(rest) = s.strip_prefix("tcp:") {
            if rest.contains(':') {
                return Ok(Endpoint::Tcp(rest.to_string()));
            }
            return Err(format!("tcp endpoint needs HOST:PORT, got {rest:?}"));
        }
        if let Some(rest) = s.strip_prefix("uds:") {
            if rest.is_empty() {
                return Err("uds endpoint needs a socket path".to_string());
            }
            return Ok(Endpoint::Uds(PathBuf::from(rest)));
        }
        if s.contains('/') {
            return Ok(Endpoint::Uds(PathBuf::from(s)));
        }
        if s.contains(':') {
            return Ok(Endpoint::Tcp(s.to_string()));
        }
        Err(format!("cannot parse endpoint {s:?}: use tcp:HOST:PORT or uds:/path.sock"))
    }

    /// Connect to this endpoint as a client (the worker side).
    pub fn connect(&self) -> io::Result<Box<dyn Transport>> {
        Ok(match self {
            Endpoint::Tcp(addr) => Box::new(SocketTransport::tcp(TcpStream::connect(addr)?)),
            Endpoint::Uds(path) => Box::new(SocketTransport::uds(UnixStream::connect(path)?)),
        })
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Tcp(addr) => write!(f, "tcp:{addr}"),
            Endpoint::Uds(path) => write!(f, "uds:{}", path.display()),
        }
    }
}

/// Server side of an [`Endpoint`]: accepts worker links.
pub enum Listener {
    /// Bound TCP listener.
    Tcp(TcpListener),
    /// Bound Unix-domain listener (the socket file is removed on bind if
    /// a previous run left it behind).
    Uds(UnixListener),
}

impl Listener {
    /// Bind the endpoint for accepting workers.
    pub fn bind(ep: &Endpoint) -> io::Result<Listener> {
        Ok(match ep {
            Endpoint::Tcp(addr) => Listener::Tcp(TcpListener::bind(addr)?),
            Endpoint::Uds(path) => {
                let _ = std::fs::remove_file(path); // stale socket from a crash
                Listener::Uds(UnixListener::bind(path)?)
            }
        })
    }

    /// Block until one worker connects; returns the link and a peer label
    /// for logs.
    pub fn accept(&self) -> io::Result<(Box<dyn Transport>, String)> {
        Ok(match self {
            Listener::Tcp(l) => {
                let (s, peer) = l.accept()?;
                (Box::new(SocketTransport::tcp(s)) as Box<dyn Transport>, peer.to_string())
            }
            Listener::Uds(l) => {
                let (s, _) = l.accept()?;
                (Box::new(SocketTransport::uds(s)) as Box<dyn Transport>, "uds-peer".to_string())
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn channel_pair_exchanges_messages() {
        let (mut a, mut b) = ChannelTransport::pair();
        a.send(&WireMsg::Request { amount: 5 }).unwrap();
        b.send(&WireMsg::Ping).unwrap();
        assert_eq!(
            b.recv_timeout(Duration::from_secs(1)).unwrap(),
            WireMsg::Request { amount: 5 }
        );
        assert_eq!(a.recv_timeout(Duration::from_secs(1)).unwrap(), WireMsg::Ping);
        assert!(matches!(
            a.recv_timeout(Duration::from_millis(5)),
            Err(TransportError::Timeout)
        ));
        let s = a.stats();
        assert_eq!((s.msgs_out, s.msgs_in, s.bytes_out), (1, 1, 0));
    }

    #[test]
    fn channel_split_halves_route_one_direction_each() {
        let (a, mut b) = ChannelTransport::pair();
        let (mut tx, mut rx) = (Box::new(a) as Box<dyn Transport>).split().unwrap();
        tx.send(&WireMsg::RecallAck).unwrap();
        assert_eq!(b.recv_timeout(Duration::from_secs(1)).unwrap(), WireMsg::RecallAck);
        b.send(&WireMsg::Shutdown).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), WireMsg::Shutdown);
        assert!(matches!(rx.send(&WireMsg::Ping), Err(TransportError::Closed(_))));
        assert!(matches!(
            tx.recv_timeout(Duration::from_millis(1)),
            Err(TransportError::Closed(_))
        ));
    }

    #[test]
    fn channel_drop_surfaces_as_closed() {
        let (mut a, b) = ChannelTransport::pair();
        drop(b);
        assert!(matches!(a.send(&WireMsg::Ping), Err(TransportError::Closed(_))));
        assert!(matches!(
            a.recv_timeout(Duration::from_millis(5)),
            Err(TransportError::Closed(_))
        ));
    }

    #[test]
    fn tcp_loopback_roundtrip_counts_bytes() {
        let listener = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
        let addr = match &listener {
            Listener::Tcp(l) => l.local_addr().unwrap().to_string(),
            _ => unreachable!(),
        };
        let client = thread::spawn(move || {
            let mut t = Endpoint::Tcp(addr).connect().unwrap();
            t.send(&WireMsg::Hello { version: wire::PROTO_VERSION, requested_np: 2 }).unwrap();
            let got = t.recv_timeout(Duration::from_secs(5)).unwrap();
            (got, t.stats())
        });
        let (mut server, _peer) = listener.accept().unwrap();
        let hello = server.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(hello, WireMsg::Hello { version: wire::PROTO_VERSION, requested_np: 2 });
        server.send(&WireMsg::Cancel { id: 9 }).unwrap();
        let (got, cstats) = client.join().unwrap();
        assert_eq!(got, WireMsg::Cancel { id: 9 });
        let sstats = server.stats();
        assert!(sstats.bytes_in > 0 && sstats.bytes_out > 0);
        assert_eq!(sstats.bytes_in, cstats.bytes_out);
        assert_eq!(sstats.bytes_out, cstats.bytes_in);
        assert_eq!((sstats.msgs_in, sstats.msgs_out), (1, 1));
    }

    #[test]
    fn uds_roundtrip_and_peer_close() {
        let path = std::env::temp_dir().join(format!("caravan_t_{}.sock", std::process::id()));
        let ep = Endpoint::Uds(path.clone());
        let listener = Listener::bind(&ep).unwrap();
        let ep2 = ep.clone();
        let client = thread::spawn(move || {
            let mut t = ep2.connect().unwrap();
            t.send(&WireMsg::Request { amount: 1 }).unwrap();
            // Drop without further traffic: the server sees a clean close.
        });
        let (mut server, _) = listener.accept().unwrap();
        assert_eq!(
            server.recv_timeout(Duration::from_secs(5)).unwrap(),
            WireMsg::Request { amount: 1 }
        );
        client.join().unwrap();
        assert!(matches!(
            server.recv_timeout(Duration::from_secs(5)),
            Err(TransportError::Closed(_))
        ));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn endpoint_parsing_spellings() {
        assert_eq!(
            Endpoint::parse("tcp:10.0.0.1:7000"),
            Ok(Endpoint::Tcp("10.0.0.1:7000".into()))
        );
        assert_eq!(
            Endpoint::parse("uds:/tmp/x.sock"),
            Ok(Endpoint::Uds(PathBuf::from("/tmp/x.sock")))
        );
        assert_eq!(
            Endpoint::parse("/tmp/x.sock"),
            Ok(Endpoint::Uds(PathBuf::from("/tmp/x.sock")))
        );
        assert_eq!(Endpoint::parse("host:9"), Ok(Endpoint::Tcp("host:9".into())));
        assert!(Endpoint::parse("tcp:nohostport").is_err());
        assert!(Endpoint::parse("garbage").is_err());
        assert!(Endpoint::parse("uds:").is_err());
        assert_eq!(Endpoint::parse("uds:/a/b").unwrap().to_string(), "uds:/a/b");
    }
}
