//! Tiny command-line parser for the `caravan` binary, examples and benches.
//!
//! Grammar: `prog [subcommand] [--key value | --flag] [positional…]`.
//! Typed getters with defaults keep call sites short:
//!
//! ```
//! use caravan::util::cli::Args;
//! let a = Args::parse_from(vec!["des".into(), "--np".into(), "1024".into()]);
//! assert_eq!(a.subcommand(), Some("des"));
//! assert_eq!(a.get_usize("np", 256), 1024);
//! ```

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    sub: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1).collect())
    }

    pub fn parse_from(argv: Vec<String>) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.sub = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag.
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.sub.as_deref()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get_opt(key).unwrap_or(default)
    }

    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.try_opt(key).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    }

    /// Fallible option lookup: `Ok(None)` when `--key` was not given at
    /// all, `Err` (naming the flag) when it was given *bare* — at the end
    /// of the argument list, or directly followed by another `--option` —
    /// so the value it needed never arrived. Without this check a typo
    /// like `caravan des --np --steal` silently ran with the default np.
    pub fn try_opt(&self, key: &str) -> Result<Option<&str>, String> {
        match self.opts.get(key) {
            Some(v) => Ok(Some(v.as_str())),
            None if self.has_flag(key) => {
                Err(format!("--{key} requires a value (write `--{key} VALUE` or `--{key}=VALUE`)"))
            }
            None => Ok(None),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.opt_parse(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.opt_parse(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.opt_parse(key).unwrap_or(default)
    }

    fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get_opt(key).map(|v| {
            v.parse().unwrap_or_else(|_| panic!("--{key}: cannot parse {v:?}"))
        })
    }

    /// Comma-separated list, e.g. `--np 256,1024,4096`.
    pub fn get_list_usize(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get_opt(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad item {t:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse_from(sv(&["des", "--np", "1024", "--tc", "2", "--verbose"]));
        assert_eq!(a.subcommand(), Some("des"));
        assert_eq!(a.get_usize("np", 1), 1024);
        assert_eq!(a.get_str("tc", "1"), "2");
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = Args::parse_from(sv(&["--rate=0.5", "--name=x"]));
        assert_eq!(a.subcommand(), None);
        assert_eq!(a.get_f64("rate", 0.0), 0.5);
        assert_eq!(a.get_str("name", ""), "x");
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn positional_and_lists() {
        let a = Args::parse_from(sv(&["run", "cmd.sh", "--np", "1,2,3"]));
        assert_eq!(a.positional(), &["cmd.sh".to_string()]);
        assert_eq!(a.get_list_usize("np", &[]), vec![1, 2, 3]);
        assert_eq!(a.get_list_usize("other", &[9]), vec![9]);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_numeric_panics() {
        let a = Args::parse_from(sv(&["--np", "abc"]));
        a.get_usize("np", 0);
    }

    #[test]
    fn bare_value_flag_is_a_usage_error_naming_the_flag() {
        // `--np` at the end of argv: the value never arrived.
        let a = Args::parse_from(sv(&["des", "--np"]));
        let err = a.try_opt("np").unwrap_err();
        assert!(err.contains("--np"), "error must name the flag: {err}");
        assert!(err.contains("requires a value"), "unexpected message: {err}");

        // `--np --steal`: the next option swallowed the value slot.
        let a = Args::parse_from(sv(&["des", "--np", "--steal"]));
        assert!(a.try_opt("np").is_err());
        // The genuine flag is still a flag, and untouched keys still miss.
        assert!(a.has_flag("steal"));
        assert_eq!(a.try_opt("fanout"), Ok(None));

        // A key that did get a value is unaffected.
        let a = Args::parse_from(sv(&["des", "--np", "4"]));
        assert_eq!(a.try_opt("np"), Ok(Some("4")));
    }
}
