//! Deterministic pseudo-random number generation.
//!
//! [`Pcg64`] is the PCG-XSL-RR 128/64 generator (O'Neill 2014) — the same
//! algorithm as `rand_pcg::Pcg64`. It is seeded through SplitMix64 so that
//! small human-chosen seeds (0, 1, 2…) produce well-mixed streams, and it
//! supports cheap independent sub-streams via [`Pcg64::split`], which the
//! scheduler uses to give every simulated process its own generator.

/// SplitMix64 step — used for seed expansion and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-low + random-rotate
/// output. Period 2^128 per stream; distinct odd increments give independent
/// streams.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd
}

impl Pcg64 {
    /// Create a generator from a small seed. Two generators with different
    /// seeds are statistically independent (seed is expanded via SplitMix64
    /// into both the state and the stream-selector increment).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let b = splitmix64(&mut sm);
        let c = splitmix64(&mut sm);
        let d = splitmix64(&mut sm);
        let state = ((a as u128) << 64) | b as u128;
        let inc = (((c as u128) << 64) | d as u128) | 1;
        let mut rng = Self { state: state.wrapping_add(inc), inc };
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (used to hand one RNG to each
    /// simulated process / task without sharing state across threads).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let mut s = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let a = splitmix64(&mut s);
        Pcg64::new(a)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (polar form avoided to stay branch-light).
    pub fn normal(&mut self) -> f64 {
        // Draw u in (0,1] to avoid ln(0).
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        (-2.0 * u.ln()).sqrt() * (std::f64::consts::TAU * v).cos()
    }

    /// Bounded power-law sample with density ∝ t^exponent on
    /// `[t_min, t_max]` (exponent < -1 for the paper's heavy tail of −2).
    /// Inverse-CDF sampling.
    pub fn power_law(&mut self, t_min: f64, t_max: f64, exponent: f64) -> f64 {
        debug_assert!(t_min > 0.0 && t_max > t_min);
        let u = self.uniform();
        if (exponent + 1.0).abs() < 1e-12 {
            // ∝ 1/t : log-uniform
            return t_min * (t_max / t_min).powf(u);
        }
        let a = exponent + 1.0;
        let lo = t_min.powf(a);
        let hi = t_max.powf(a);
        (lo + u * (hi - lo)).powf(1.0 / a)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        let mut c = Pcg64::new(43);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let m = sum / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut rng = Pcg64::new(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 7.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "count {c}");
        }
    }

    #[test]
    fn power_law_bounds_and_heavy_tail() {
        let mut rng = Pcg64::new(11);
        let (lo, hi, ex) = (5.0, 100.0, -2.0);
        let n = 200_000;
        let mut below10 = 0usize;
        for _ in 0..n {
            let t = rng.power_law(lo, hi, ex);
            assert!(t >= lo && t <= hi);
            if t < 10.0 {
                below10 += 1;
            }
        }
        // For exponent -2 on [5,100]: P(t<10) = (1/5-1/10)/(1/5-1/100) ≈ 0.526.
        let frac = below10 as f64 / n as f64;
        assert!((frac - 0.526).abs() < 0.01, "frac {frac}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::new(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
