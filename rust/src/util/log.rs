//! Leveled stderr logger.
//!
//! Controlled by `CARAVAN_LOG` (`error|warn|info|debug|trace`, default
//! `info`). Timestamps are milliseconds since process start so traces from
//! the threaded scheduler are easy to correlate.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_env() -> Level {
        match std::env::var("CARAVAN_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static START: OnceLock<Instant> = OnceLock::new();

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let l = Level::from_env();
        LEVEL.store(l as u8, Ordering::Relaxed);
        return l;
    }
    // SAFETY-free mapping: raw was stored from a valid Level.
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (benches silence the scheduler).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let ms = t0.elapsed().as_millis();
    eprintln!("[{:>8}ms {} {}] {}", ms, l.tag(), module, msg);
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! errorln {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! traceln {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
