//! Self-contained infrastructure: deterministic RNG, statistics, minimal
//! JSON, CLI parsing and logging.
//!
//! The reproduction environment is fully offline (only the `xla` crate's
//! dependency closure is vendored), so the usual ecosystem crates
//! (`rand`, `serde`, `clap`, `criterion`, `proptest`) are re-implemented
//! here at the scale this project needs. Everything is deterministic and
//! seedable; nothing here touches global state except [`log`].

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod log;

pub use rng::Pcg64;
pub use stats::{mean, variance, pearson, Histogram, Summary};
