//! Descriptive statistics used by the benchmark harness and the MOEA
//! post-processing: means/variances, Pearson correlation (the Fig. 5
//! upper-triangle numbers), histograms (the Fig. 5 diagonal panels) and
//! five-number summaries for bench reports.

/// Total order on `f64` that ranks NaN strictly *worst* (largest) — the
/// comparator to use wherever "smallest wins": a NaN score can then never
/// panic the sort (`partial_cmp().unwrap()`) nor win a `min_by`.
/// `f64::total_cmp` alone is not enough: it orders by bit pattern, so a
/// *negative* NaN would rank below `-inf` and win. Both NaN signs land at
/// the top here, and NaN==NaN keeps the order total.
pub fn nan_worst(a: f64, b: f64) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

/// Lexicographic [`nan_worst`] over `f64` slices: element-wise total
/// order with NaN ranked worst at every position, shorter prefix first.
/// The comparator to hand `sort_by` for point lists (`Vec<Vec<f64>>`)
/// where `partial_cmp().unwrap()` would panic on a single NaN
/// coordinate.
pub fn nan_worst_slice(a: &[f64], b: &[f64]) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    for (x, y) in a.iter().zip(b.iter()) {
        let o = nan_worst(*x, *y);
        if o != Ordering::Equal {
            return o;
        }
    }
    a.len().cmp(&b.len())
}

/// [`nan_worst`] for `f32`.
pub fn nan_worst_f32(a: f32, b: f32) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Pearson's correlation coefficient. Returns `f64::NAN` when either input
/// is (numerically) constant — matching the undefined case.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    let n = xs.len();
    if n < 2 {
        return f64::NAN;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return f64::NAN;
    }
    sxy / (sxx * syy).sqrt()
}

/// Quantile with linear interpolation (type-7, the numpy default).
/// `q` in [0,1]; input need not be sorted. NaN values sort to the top
/// (`total_cmp` order) instead of panicking the sort, so only the upper
/// quantiles of NaN-contaminated data are themselves NaN.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Fixed-width histogram over `[lo, hi]`; values outside are clamped into
/// the edge bins (the MOEA objective values are bounded so this is benign).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Self { lo, hi, counts: vec![0; bins] }
    }

    /// Build a histogram spanning the data range.
    pub fn from_data(xs: &[f64], bins: usize) -> Self {
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let (lo, hi) = if lo < hi { (lo, hi) } else { (lo - 0.5, lo + 0.5) };
        let mut h = Self::new(lo, hi, bins);
        for &x in xs {
            h.add(x);
        }
        h
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Render as a one-line ASCII sparkline (used in bench reports).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().cloned().max().unwrap_or(0).max(1);
        self.counts
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

/// Five-number-plus-mean summary for bench reporting.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty());
        Self {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50: quantile(xs, 0.5),
            p95: quantile(xs, 0.95),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} std={:.4} min={:.4} p50={:.4} p95={:.4} max={:.4}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_worst_ranks_both_nan_signs_last() {
        use std::cmp::Ordering;
        for bad in [f64::NAN, -f64::NAN] {
            assert_eq!(nan_worst(bad, f64::INFINITY), Ordering::Greater);
            assert_eq!(nan_worst(f64::NEG_INFINITY, bad), Ordering::Less);
            assert_eq!(nan_worst(bad, bad), Ordering::Equal);
        }
        assert_eq!(nan_worst(1.0, 2.0), Ordering::Less);
        for bad in [f32::NAN, -f32::NAN] {
            assert_eq!(nan_worst_f32(bad, 0.0), Ordering::Greater);
            assert_eq!(nan_worst_f32(0.0, bad), Ordering::Less);
        }
        let mut v = vec![3.0, f64::NAN, 1.0, -f64::NAN, 2.0];
        v.sort_by(|a, b| nan_worst(*a, *b));
        assert_eq!(&v[..3], &[1.0, 2.0, 3.0], "finite values first, NaNs at the end");
        assert!(v[3].is_nan() && v[4].is_nan());
    }

    #[test]
    fn mean_variance_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
        assert!(pearson(&xs, &[1.0, 1.0, 1.0, 1.0]).is_nan());
    }

    #[test]
    fn pearson_zero_for_orthogonal() {
        let xs = [1.0, -1.0, 1.0, -1.0];
        let ys = [1.0, 1.0, -1.0, -1.0];
        assert!(pearson(&xs, &ys).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_tolerates_nan_without_panicking() {
        // Regression: the sort comparator used to be
        // `partial_cmp().unwrap()`, so one NaN measurement panicked any
        // bench summary. NaN now sorts to the top; lower quantiles of the
        // finite mass stay exact.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0 / 3.0) - 2.0).abs() < 1e-12);
        assert!(quantile(&xs, 1.0).is_nan(), "NaN occupies the maximum");
        let all_nan = [f64::NAN, f64::NAN];
        assert!(quantile(&all_nan, 0.5).is_nan());
        // Summary over NaN-contaminated data must not panic either.
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        assert!((s.min - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nan_worst_slice_is_lexicographic_and_nan_safe() {
        use std::cmp::Ordering;
        assert_eq!(nan_worst_slice(&[0.0, 1.0], &[0.0, 2.0]), Ordering::Less);
        assert_eq!(nan_worst_slice(&[1.0], &[1.0]), Ordering::Equal);
        // Shorter prefix ranks first.
        assert_eq!(nan_worst_slice(&[1.0], &[1.0, 0.0]), Ordering::Less);
        // NaN ranks worst at any position instead of panicking the sort.
        assert_eq!(nan_worst_slice(&[f64::NAN, 0.0], &[9.0, 9.0]), Ordering::Greater);
        assert_eq!(nan_worst_slice(&[0.0, f64::NAN], &[0.0, 9.0]), Ordering::Greater);
        let mut pts = vec![vec![1.0, f64::NAN], vec![0.0, 0.0], vec![f64::NAN, 0.0]];
        pts.sort_by(|a, b| nan_worst_slice(a, b));
        assert_eq!(pts[0], vec![0.0, 0.0]);
        assert!(pts[2][0].is_nan(), "whole-slice NaN head sorts last");
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.7, 9.9, -5.0, 15.0] {
            h.add(x);
        }
        assert_eq!(h.counts[0], 2); // 0.5 and clamped -5.0
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 2); // 9.9 and clamped 15.0
        assert_eq!(h.total(), 6);
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 3.0);
    }
}
