//! Minimal JSON reader/writer.
//!
//! Used for the artifact metadata (`artifacts/meta.json` written by the
//! python AOT step), for `_results.txt`-adjacent structured outputs, and
//! for bench reports. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (not needed by any producer in this repo).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset where the parser gave up.
/// Hand-rolled `Display`/`Error` impls keep the crate dependency-free
/// (`Cargo.toml` declares no dependencies, so a `thiserror` derive here
/// would not even build).
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Convenience: `obj.get_f64("x")` for required numeric fields.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Collect the longest run of plain bytes (valid UTF-8 by input contract).
                    let start = self.pos - 1;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_error_displays_position_and_message() {
        let err = Json::parse("[1,").unwrap_err();
        let shown = err.to_string();
        assert!(shown.starts_with("json parse error at byte "), "got: {shown}");
        let _dyn_err: &dyn std::error::Error = &err;
    }

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e1], "c": "x\ny", "d": {}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get_f64("a"), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::Str("a\u{1}b".to_string()).to_string();
        assert_eq!(s, "\"a\\u0001b\"");
    }
}
