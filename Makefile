# Top-level convenience targets.
#
# `artifacts` builds the AOT-compiled JAX/Pallas artifacts consumed by
# the PJRT integration tests (rust/tests/integration.rs) and by
# `caravan evac --backend pjrt`. It needs the python toolchain (jax +
# xla_extension); the rust crate builds and tests fine without it — the
# PJRT-dependent test cases skip when artifacts/meta.json is absent.

ARTIFACTS := rust/artifacts

.PHONY: artifacts test bench-smoke clean-artifacts

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

test:
	cargo build --release
	cargo test -q

bench-smoke:
	cargo bench --bench fig3_tree -- --quick

clean-artifacts:
	rm -rf $(ARTIFACTS)
