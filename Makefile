# Top-level convenience targets.
#
# `artifacts` builds the AOT-compiled JAX/Pallas artifacts consumed by
# the PJRT integration tests (rust/tests/integration.rs) and by
# `caravan evac --backend pjrt`. It needs the python toolchain (jax +
# xla_extension); the rust crate builds and tests fine without it — the
# PJRT-dependent test cases skip when artifacts/meta.json is absent.

ARTIFACTS := rust/artifacts

.PHONY: artifacts test bench-smoke fig3-artifact clean-artifacts

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

test:
	cargo build --release
	cargo test -q

bench-smoke:
	cargo bench --bench fig3_tree -- --quick --check-schema BENCH_fig3.json

# Regenerate the tracked full-scale depth-sweep table (deterministic DES:
# same code + config => identical metric values). CI schema-checks it on
# every run via bench-smoke.
fig3-artifact:
	cargo bench --bench fig3_tree -- --json BENCH_fig3.json

clean-artifacts:
	rm -rf $(ARTIFACTS)
