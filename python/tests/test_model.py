"""Layer-2 correctness: the scanned evacuation model.

Checks: pallas-backed scan vs pure-jnp oracle scan, physical sanity
(monotone arrivals, congestion slowdown, penalty at horizon), and shape
stability for the AOT variants.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import evac_run, evac_run_ref

jax.config.update("jax_platform_name", "cpu")

BIG = 1e9
PHYS = dict(dt=1.0, v_free=1.0, rho_jam=10.0, v_min_frac=0.05, penalty=1000.0)


def line_world(n_agents, spread=0.0):
    """Two 100 m links in a line; shelter at node 2 (matches the rust
    sim.rs unit fixture)."""
    length = jnp.asarray([100.0, 100.0, BIG], jnp.float32)
    to = jnp.asarray([1, 2, 0], jnp.int32)
    next_link = jnp.asarray([0, 1, 0], jnp.int32)
    shelter = jnp.asarray([2], jnp.int32)
    link = jnp.zeros((n_agents,), jnp.int32)
    pos = jnp.asarray(np.linspace(0.0, spread, n_agents), jnp.float32)
    dest = jnp.zeros((n_agents,), jnp.int32)
    return link, pos, dest, length, to, next_link, shelter


def test_single_agent_time_matches_rust_fixture():
    # rust/src/evac/sim.rs::single_agent_walks_the_line_and_arrives
    # expects ~201 steps for 200 m at ~1 m/s.
    args = line_world(1)
    f1, remaining, arrivals = evac_run(*args, steps=400, **PHYS)
    assert float(remaining) == 0.0
    assert abs(float(f1) - 201.0) <= 2.0, f"f1={float(f1)}"
    assert arrivals.shape == (400,)


def test_model_matches_oracle_scan():
    args = line_world(64, spread=90.0)
    f1a, rema, arra = evac_run(*args, steps=350, **PHYS)
    f1b, remb, arrb = evac_run_ref(*args, steps=350, **PHYS)
    assert float(rema) == float(remb)
    # Arrival curves may shift by at most one step on FMA-borderline
    # transitions; for this fixture they agree exactly.
    np.testing.assert_allclose(np.asarray(arra), np.asarray(arrb), atol=1.0)
    assert abs(float(f1a) - float(f1b)) <= PHYS["dt"] * 2


def test_congestion_slows_crowd():
    # Jam density 2.0: 150 agents on a 100 m link give rho = 1.5 and the
    # speed factor drops to 0.25 -> roughly 4x slower than the lone agent.
    phys = dict(PHYS, rho_jam=2.0)
    f1_lone, _, _ = evac_run(*line_world(1), steps=3000, **phys)
    f1_crowd, rem, _ = evac_run(*line_world(150), steps=3000, **phys)
    assert float(rem) == 0.0
    assert float(f1_crowd) > 1.5 * float(f1_lone)


def test_penalty_on_horizon_hit():
    f1, remaining, _ = evac_run(*line_world(1), steps=50, **PHYS)
    assert float(remaining) == 1.0
    assert abs(float(f1) - (50.0 + 1000.0)) < 1e-3


def test_arrivals_monotone_nondecreasing():
    _, _, arrivals = evac_run(*line_world(32, spread=99.0), steps=300, **PHYS)
    a = np.asarray(arrivals)
    assert (np.diff(a) >= -1e-6).all()
    assert a[-1] == 32


def test_aot_variant_shapes_lower():
    """The tiny AOT variant lowers and runs with its exact baked shapes."""
    from compile.aot import VARIANTS, PHYSICS

    spec = VARIANTS["tiny"]
    a, l, n, s = spec["A"], spec["L"], spec["N"], spec["S"]
    rng = np.random.default_rng(0)
    link = jnp.asarray(rng.integers(0, l, a), jnp.int32)
    pos = jnp.zeros((a,), jnp.float32)
    dest = jnp.asarray(rng.integers(0, s, a), jnp.int32)
    length = jnp.asarray(
        np.concatenate([rng.uniform(50, 120, l), [BIG]]), jnp.float32)
    to = jnp.asarray(np.concatenate([rng.integers(0, n, l), [0]]), jnp.int32)
    next_link = jnp.asarray(rng.integers(0, l, n * s), jnp.int32)
    shelter = jnp.asarray(rng.choice(n, s, replace=False), jnp.int32)
    # Short horizon for speed; same shapes otherwise.
    f1, remaining, arrivals = evac_run(
        link, pos, dest, length, to, next_link, shelter,
        steps=16, **PHYSICS)
    assert np.isfinite(float(f1))
    assert arrivals.shape == (16,)
