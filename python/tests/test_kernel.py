"""Layer-1 correctness: the Pallas kernel against the pure-jnp oracle.

This is the CORE correctness signal for the compiled stack: the kernel
must match ``ref.speed_advance_ref`` bit-for-bit (identical f32 ops), over
hypothesis-generated networks, agent states and physics parameters.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import link_speeds, speed_advance_ref, step_ref
from compile.kernels.speed_advance import speed_advance, TILE

jax.config.update("jax_platform_name", "cpu")

BIG = 1e9


def toy_network(n_links, n_nodes, n_shelters, rng):
    """Random network arrays in canonical (padded) form."""
    length = np.concatenate([
        rng.uniform(5.0, 200.0, n_links).astype(np.float32), [BIG]])
    to = np.concatenate([
        rng.integers(0, n_nodes, n_links).astype(np.int32), [0]])
    next_link = rng.integers(0, n_links, n_nodes * n_shelters).astype(np.int32)
    shelter_node = rng.choice(n_nodes, size=n_shelters,
                              replace=False).astype(np.int32)
    return length, to, next_link, shelter_node


def toy_agents(n_agents, n_links, n_shelters, rng, arrived_frac=0.1):
    link = rng.integers(0, n_links, n_agents).astype(np.int32)
    arrived = rng.uniform(size=n_agents) < arrived_frac
    link[arrived] = n_links
    pos = rng.uniform(0.0, 200.0, n_agents).astype(np.float32)
    pos[arrived] = 0.0
    dest = rng.integers(0, n_shelters, n_agents).astype(np.int32)
    return link, pos, dest


@pytest.mark.parametrize("seed", range(4))
def test_kernel_matches_ref_exactly(seed):
    rng = np.random.default_rng(seed)
    n_links, n_nodes, n_shelters, n_agents = 37, 20, 4, 2 * TILE
    length, to, next_link, shelter_node = toy_network(
        n_links, n_nodes, n_shelters, rng)
    link, pos, dest = toy_agents(n_agents, n_links, n_shelters, rng)
    v = link_speeds(jnp.asarray(link), jnp.asarray(length),
                    v_free=1.4, rho_jam=2.0, v_min_frac=0.05)

    got_link, got_pos = speed_advance(
        jnp.asarray(link), jnp.asarray(pos), jnp.asarray(dest), v,
        jnp.asarray(length), jnp.asarray(to), jnp.asarray(next_link),
        jnp.asarray(shelter_node), dt=2.0)
    want_link, want_pos = speed_advance_ref(
        jnp.asarray(link), jnp.asarray(pos), jnp.asarray(dest), v,
        jnp.asarray(length), jnp.asarray(to), jnp.asarray(next_link),
        jnp.asarray(shelter_node), dt=2.0)

    # Discrete state must agree exactly; positions may differ by one ulp
    # because XLA fuses mul+add into FMA differently per jit.
    np.testing.assert_array_equal(np.asarray(got_link), np.asarray(want_link))
    np.testing.assert_allclose(np.asarray(got_pos), np.asarray(want_pos),
                               rtol=1e-6, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_links=st.integers(1, 300),
    n_shelters=st.integers(1, 16),
    tiles=st.integers(1, 3),
    dt=st.floats(0.5, 5.0),
    v_free=st.floats(0.5, 3.0),
)
def test_kernel_matches_ref_hypothesis(seed, n_links, n_shelters, tiles,
                                       dt, v_free):
    """Hypothesis sweep over shapes and physics parameters."""
    rng = np.random.default_rng(seed)
    n_nodes = max(n_shelters, rng.integers(n_shelters, n_shelters + 50))
    n_agents = tiles * TILE
    length, to, next_link, shelter_node = toy_network(
        n_links, n_nodes, n_shelters, rng)
    link, pos, dest = toy_agents(n_agents, n_links, n_shelters, rng)
    v = link_speeds(jnp.asarray(link), jnp.asarray(length),
                    v_free=v_free, rho_jam=2.0, v_min_frac=0.05)
    args = (jnp.asarray(link), jnp.asarray(pos), jnp.asarray(dest), v,
            jnp.asarray(length), jnp.asarray(to), jnp.asarray(next_link),
            jnp.asarray(shelter_node))
    got_link, got_pos = speed_advance(*args, dt=dt)
    want_link, want_pos = speed_advance_ref(*args, dt=dt)
    np.testing.assert_array_equal(np.asarray(got_link), np.asarray(want_link))
    # One-ulp FMA slack (see test_kernel_matches_ref_exactly).
    np.testing.assert_allclose(np.asarray(got_pos), np.asarray(want_pos),
                               rtol=1e-6, atol=1e-4)


def test_arrived_agents_never_move():
    rng = np.random.default_rng(0)
    n_links, n_nodes, n_shelters = 10, 8, 2
    length, to, next_link, shelter_node = toy_network(
        n_links, n_nodes, n_shelters, rng)
    link = np.full(TILE, n_links, np.int32)  # everyone already arrived
    pos = np.zeros(TILE, np.float32)
    dest = np.zeros(TILE, np.int32)
    v = link_speeds(jnp.asarray(link), jnp.asarray(length),
                    v_free=1.4, rho_jam=2.0, v_min_frac=0.05)
    new_link, new_pos = speed_advance(
        jnp.asarray(link), jnp.asarray(pos), jnp.asarray(dest), v,
        jnp.asarray(length), jnp.asarray(to), jnp.asarray(next_link),
        jnp.asarray(shelter_node), dt=2.0)
    np.testing.assert_array_equal(np.asarray(new_link), link)
    np.testing.assert_array_equal(np.asarray(new_pos), pos)


def test_congestion_reduces_speed():
    # Crowded link slower than empty link.
    length = jnp.asarray([100.0, 100.0, BIG], jnp.float32)
    link = jnp.asarray([0] * 150 + [1], jnp.int32)
    v = link_speeds(link, length, v_free=1.4, rho_jam=2.0, v_min_frac=0.05)
    assert float(v[0]) < float(v[1])
    assert float(v[0]) >= 1.4 * 0.05 - 1e-6
    assert float(v[2]) == 0.0  # sentinel row zeroed


def test_step_ref_transition_and_arrival():
    # Two-link line, one agent at the end of link 0 moving to shelter at
    # node 2: step 1 transitions to link 1; placing it at the end of link 1
    # arrives next step.
    length = jnp.asarray([10.0, 10.0, BIG], jnp.float32)
    to = jnp.asarray([1, 2, 0], jnp.int32)
    next_link = jnp.asarray([0, 1, 0], jnp.int32)  # N=3 nodes, S=1
    shelter = jnp.asarray([2], jnp.int32)
    link = jnp.asarray([0], jnp.int32)
    pos = jnp.asarray([9.5], jnp.float32)
    dest = jnp.asarray([0], jnp.int32)
    kw = dict(dt=1.0, v_free=1.0, rho_jam=100.0, v_min_frac=0.05)
    l1, p1 = step_ref(link, pos, dest, length, to, next_link, shelter, **kw)
    assert int(l1[0]) == 1
    assert 0.0 <= float(p1[0]) < 1.0
    l2, p2 = step_ref(jnp.asarray([1], jnp.int32), jnp.asarray([9.9], jnp.float32),
                      dest, length, to, next_link, shelter, **kw)
    assert int(l2[0]) == 2  # sentinel: arrived
    assert float(p2[0]) == 0.0
