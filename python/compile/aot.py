"""AOT export: lower the Layer-2 model to HLO *text* for the rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (one per scenario class; shapes must match
``rust/src/evac/scenario.rs``):

  artifacts/evac_tiny.hlo.txt   A=256,  L=98,   N=30,  S=3,  T=512
  artifacts/evac_mini.hlo.txt   A=4096, L=1520, N=400, S=12, T=1024
  artifacts/meta.json           shape + physics table consumed by rust

Usage: python -m compile.aot --out ../artifacts   (from python/)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import evac_run

# Physics constants — keep identical to SimParams::default() in
# rust/src/evac/sim.rs.
PHYSICS = dict(dt=2.0, v_free=1.4, rho_jam=4.0, v_min_frac=0.10,
               penalty=600.0)

# Scenario classes — keep identical to ScenarioParams::{tiny,yodogawa_mini}
# (A = n_agents, L = padded full-grid links, N = nodes, S = shelters,
# T = sim.max_steps).
VARIANTS = {
    "tiny": dict(A=256, L=98, N=30, S=3, T=512),
    "mini": dict(A=4096, L=1520, N=400, S=12, T=1024),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_variant(spec):
    a, l, n, s, t = spec["A"], spec["L"], spec["N"], spec["S"], spec["T"]

    def fn(link, pos, dest, length, to, next_link, shelter_node):
        return evac_run(link, pos, dest, length, to, next_link,
                        shelter_node, steps=t, **PHYSICS)

    args = (
        jax.ShapeDtypeStruct((a,), jnp.int32),        # link
        jax.ShapeDtypeStruct((a,), jnp.float32),      # pos
        jax.ShapeDtypeStruct((a,), jnp.int32),        # dest
        jax.ShapeDtypeStruct((l + 1,), jnp.float32),  # length
        jax.ShapeDtypeStruct((l + 1,), jnp.int32),    # to
        jax.ShapeDtypeStruct((n * s,), jnp.int32),    # next_link
        jax.ShapeDtypeStruct((s,), jnp.int32),        # shelter_node
    )
    return jax.jit(fn).lower(*args)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory")
    ap.add_argument("--variants", default="tiny,mini")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meta = {"physics": PHYSICS, "variants": {}}
    for name in args.variants.split(","):
        spec = VARIANTS[name]
        lowered = lower_variant(spec)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"evac_{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["variants"][name] = dict(spec, file=f"evac_{name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars) spec={spec}")

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'meta.json')}")


if __name__ == "__main__":
    main()
