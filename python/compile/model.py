"""Layer-2 JAX model: the full evacuation simulation as a fixed-shape
``lax.scan``, calling the Layer-1 Pallas kernel each step.

One compiled artifact serves every evacuation plan on a given scenario
class: the host (rust) computes the initial agent state and the network /
routing arrays and passes them as inputs; scenario *shapes* (A, L, N, S)
and physics constants (dt, v_free, rho_jam, v_min_frac, penalty, T) are
baked at AOT time (``aot.py``).

Outputs per run:
  f1_seconds  f32[]   dt * (#steps with unfinished evacuation)
                      + penalty * (#agents still en route at T)
  remaining   f32[]   agents still en route at T
  arrivals    f32[T]  cumulative arrivals after each step

The update semantics are the canonical model of rust/src/evac/sim.rs.
"""

from functools import partial

import jax
import jax.numpy as jnp

from compile.kernels.speed_advance import speed_advance
from compile.kernels.ref import link_speeds


@partial(jax.jit, static_argnames=(
    "steps", "dt", "v_free", "rho_jam", "v_min_frac", "penalty"))
def evac_run(link, pos, dest, length, to, next_link, shelter_node, *,
             steps, dt, v_free, rho_jam, v_min_frac, penalty):
    """Run the evacuation for ``steps`` steps. See module docstring."""
    from compile.kernels.speed_advance import TILE

    n_agents = link.shape[0]
    # Pad the agent axis to the kernel tile with already-arrived sentinels
    # (link = L): they never move, never count (subtracted from arrivals).
    pad = (-n_agents) % TILE
    if pad:
        sentinel = length.shape[0] - 1
        link = jnp.concatenate([link, jnp.full((pad,), sentinel, jnp.int32)])
        pos = jnp.concatenate([pos, jnp.zeros((pad,), jnp.float32)])
        dest = jnp.concatenate([dest, jnp.zeros((pad,), jnp.int32)])

    def step(carry, _):
        lnk, p = carry
        # Density -> per-link speed (L2: scatter-add segment sum; the
        # sentinel row is zeroed so arrived agents stay put).
        v = link_speeds(lnk, length, v_free=v_free, rho_jam=rho_jam,
                        v_min_frac=v_min_frac)
        # L1 Pallas kernel: fused gather/advance/transition/arrival.
        new_link, new_pos = speed_advance(
            lnk, p, dest, v, length, to, next_link, shelter_node, dt=dt)
        arrived = jnp.sum((new_link == length.shape[0] - 1).astype(jnp.float32))
        return (new_link, new_pos), arrived

    (final_link, _), arrivals = jax.lax.scan(
        step, (link, pos), None, length=steps)
    arrivals = arrivals - jnp.float32(pad)  # drop padded sentinels
    n = jnp.float32(n_agents)
    remaining = n - arrivals[-1]
    steps_not_done = jnp.sum((arrivals < n).astype(jnp.float32))
    f1 = dt * steps_not_done + penalty * remaining
    del final_link
    return f1, remaining, arrivals


def evac_run_ref(link, pos, dest, length, to, next_link, shelter_node, *,
                 steps, dt, v_free, rho_jam, v_min_frac, penalty):
    """Oracle twin of ``evac_run`` built from ref.step_ref (no pallas)."""
    from compile.kernels.ref import step_ref

    n_agents = link.shape[0]

    def step(carry, _):
        lnk, p = carry
        new_link, new_pos = step_ref(
            lnk, p, dest, length, to, next_link, shelter_node,
            dt=dt, v_free=v_free, rho_jam=rho_jam, v_min_frac=v_min_frac)
        arrived = jnp.sum((new_link == length.shape[0] - 1).astype(jnp.float32))
        return (new_link, new_pos), arrived

    (_, _), arrivals = jax.lax.scan(step, (link, pos), None, length=steps)
    n = jnp.float32(n_agents)
    remaining = n - arrivals[-1]
    steps_not_done = jnp.sum((arrivals < n).astype(jnp.float32))
    f1 = dt * steps_not_done + penalty * remaining
    return f1, remaining, arrivals
