"""Layer-1 Pallas kernel: the per-step agent update of the evacuation
simulator — the compute hot-spot the whole stack schedules 10^5 times.

For each agent tile the kernel fuses:

  gather(link speed)  ->  position advance  ->  link-end test  ->
  transition (next_link routing-table gather)  /  arrival test

into one VMEM-resident pass. The agent arrays are tiled with ``BlockSpec``
(``TILE`` agents per grid step); the per-link tables (speed, length,
to-node) and the routing table are small (<= a few thousand entries) and
are mapped whole into every grid step -- the TPU analogue of keeping the
road network in shared memory (DESIGN.md par.Hardware-Adaptation).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode traces the kernel into plain HLO so the same
artifact runs under the rust runtime. Real-TPU estimates live in
DESIGN.md par.Perf.

Semantics must stay in lock-step with ``rust/src/evac/sim.rs`` (the
canonical reference) and ``kernels/ref.py`` (the jnp oracle).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Agent-tile sizing. Perf pass result (EXPERIMENTS.md par.Perf): on the CPU
# interpret path, *fewer, larger* tiles win decisively -- each grid step
# costs per-op dispatch + dynamic-slice overhead, so one 4096-agent tile
# runs the mini scenario 3.3x faster than sixteen 256-agent tiles
# (70 ms -> 21 ms per evaluation). On a real TPU the same choice holds at
# these sizes: a 4096-agent tile is 6 arrays x 16 KiB = 96 KiB of VMEM,
# plus ~32 KiB of tables -- far below the ~16 MiB budget, and the larger
# tile keeps the VPU lanes full. MAX_TILE caps the tile for hypothetical
# larger scenarios; agent counts must be a multiple of TILE (smaller
# inputs) or of MAX_TILE.
MAX_TILE = 4096
TILE = 256  # minimum granularity; callers pad agent counts to this


def tile_for(n_agents):
    """Largest supported tile for `n_agents` (<= MAX_TILE, divides evenly)."""
    if n_agents <= MAX_TILE:
        return n_agents
    assert n_agents % MAX_TILE == 0, n_agents
    return MAX_TILE


def _kernel(link_ref, pos_ref, dest_ref,
            v_ref, length_ref, to_ref, next_ref, shelter_ref,
            nlink_ref, npos_ref,
            *, dt, n_links, n_shelters):
    link = link_ref[...]          # i32[TILE] (n_links == arrived sentinel)
    pos = pos_ref[...]            # f32[TILE]
    dest = dest_ref[...]          # i32[TILE]

    v = v_ref[link]               # gather: per-agent speed (0 on sentinel)
    length = length_ref[link]     # gather: link length (BIG on sentinel)
    # f32 throughout: interpret mode would otherwise promote the python
    # float dt to f64 and diverge from the oracle/rust by one ulp.
    p = pos + v * jnp.float32(dt)

    at_end = p >= length
    node = to_ref[link]
    arrive = at_end & (node == shelter_ref[dest])
    nxt = next_ref[node * n_shelters + dest]

    new_link = jnp.where(at_end, jnp.where(arrive, n_links, nxt), link)
    new_pos = jnp.where(at_end, jnp.where(arrive, 0.0, p - length), p)

    nlink_ref[...] = new_link.astype(jnp.int32)
    npos_ref[...] = new_pos.astype(jnp.float32)


def speed_advance(link, pos, dest, v, length, to, next_link, shelter_node,
                  *, dt):
    """Advance all agents one step given per-link speeds ``v``.

    Args:
      link:  i32[A]  current link id (``n_links`` = arrived).
      pos:   f32[A]  position along the link (metres).
      dest:  i32[A]  destination shelter index.
      v:     f32[L+1] per-link speed this step (sentinel row = 0).
      length:f32[L+1] link lengths (sentinel row = BIG).
      to:    i32[L+1] end node per link (sentinel row = 0).
      next_link: i32[N*S] flattened routing table.
      shelter_node: i32[S].
      dt: time step (python float, baked).

    Returns:
      (new_link i32[A], new_pos f32[A]).
    """
    a = link.shape[0]
    tile = tile_for(a)
    assert a % tile == 0, f"agent count {a} must be a multiple of {tile}"
    n_links = v.shape[0] - 1
    n_shelters = shelter_node.shape[0]
    grid = (a // tile,)

    agent_spec = pl.BlockSpec((tile,), lambda i: (i,))
    full = lambda n: pl.BlockSpec((n,), lambda i: (0,))

    return pl.pallas_call(
        partial(_kernel, dt=dt, n_links=n_links, n_shelters=n_shelters),
        grid=grid,
        in_specs=[
            agent_spec, agent_spec, agent_spec,
            full(v.shape[0]), full(length.shape[0]), full(to.shape[0]),
            full(next_link.shape[0]), full(shelter_node.shape[0]),
        ],
        out_specs=[agent_spec, agent_spec],
        out_shape=[
            jax.ShapeDtypeStruct((a,), jnp.int32),
            jax.ShapeDtypeStruct((a,), jnp.float32),
        ],
        interpret=True,
    )(link, pos, dest, v, length, to, next_link, shelter_node)
