"""Pure-jnp oracle for the speed/advance kernel and the full step.

No pallas here: plain vectorized jax.numpy, structured for readability
over speed. pytest (``python/tests``) asserts the Pallas kernel matches
this oracle exactly (same f32 arithmetic), and the rust reference
simulator is cross-checked against the compiled model built from the
kernel.
"""

import jax.numpy as jnp


def link_speeds(link, length, *, v_free, rho_jam, v_min_frac):
    """Per-link congestion speeds from agent counts.

    ``link`` i32[A] (sentinel = L), ``length`` f32[L+1] (sentinel row BIG).
    Returns f32[L+1]; the sentinel row's speed is harmless (density ~ 0)
    and is zeroed explicitly so arrived agents never move.
    """
    n_rows = length.shape[0]
    cnt = jnp.zeros((n_rows,), jnp.float32).at[link].add(1.0)
    rho = cnt / length
    factor = jnp.clip(1.0 - rho / rho_jam, v_min_frac, 1.0)
    v = v_free * factor
    return v.at[n_rows - 1].set(0.0)


def speed_advance_ref(link, pos, dest, v, length, to, next_link,
                      shelter_node, *, dt):
    """Oracle for kernels.speed_advance: identical update, plain jnp."""
    n_links = v.shape[0] - 1
    n_shelters = shelter_node.shape[0]
    va = v[link]
    ln = length[link]
    p = pos + va * jnp.float32(dt)  # f32, matching the kernel and rust
    at_end = p >= ln
    node = to[link]
    arrive = at_end & (node == shelter_node[dest])
    nxt = next_link[node * n_shelters + dest]
    new_link = jnp.where(at_end, jnp.where(arrive, n_links, nxt), link)
    new_pos = jnp.where(at_end, jnp.where(arrive, 0.0, p - ln), p)
    return new_link.astype(jnp.int32), new_pos.astype(jnp.float32)


def step_ref(link, pos, dest, length, to, next_link, shelter_node, *,
             dt, v_free, rho_jam, v_min_frac):
    """One full canonical step (density -> speeds -> advance), oracle form."""
    v = link_speeds(link, length, v_free=v_free, rho_jam=rho_jam,
                    v_min_frac=v_min_frac)
    return speed_advance_ref(link, pos, dest, v, length, to, next_link,
                             shelter_node, dt=dt)
